//! Training telemetry: per-mega-batch rows, pool-membership events, CSV/JSON
//! export, and the derived measures the paper reports (time-to-accuracy,
//! statistical efficiency, best accuracy).

use std::io::Write;
use std::path::Path;

use crate::util::json::Json;
use crate::Result;

/// One row per mega-batch (the paper evaluates after every mega-batch).
/// Per-device vectors are indexed by global device id over the whole roster;
/// devices outside the active pool report zero updates / utilization /
/// merge weight.
#[derive(Clone, Debug)]
pub struct MegaBatchRow {
    pub mega_batch: usize,
    /// Training clock in seconds (virtual or wall, per engine).
    pub clock: f64,
    /// Cumulative samples processed.
    pub samples: u64,
    /// Mean training loss over the mega-batch.
    pub loss: f64,
    /// Test P@1 after merging.
    pub accuracy: f64,
    /// Per-device batch sizes in effect during this mega-batch.
    pub batch_sizes: Vec<usize>,
    /// Per-device model update counts within this mega-batch.
    pub updates: Vec<u64>,
    /// Whether Algorithm 2 applied perturbation at this merge.
    pub perturbed: bool,
    /// Simulated/measured merge (all-reduce) time in seconds.
    pub merge_time: f64,
    /// L2 norm per parameter of the merged global model.
    pub l2_per_param: f64,
    /// Per-device hardware efficiency: busy time / barrier window.
    pub utilization: Vec<f64>,
    /// Devices that participated in this mega-batch, ascending.
    pub active_devices: Vec<usize>,
    /// Algorithm 2 merge weights, scattered over the roster (inactive = 0).
    pub merge_weights: Vec<f64>,
    /// Pool membership changes applied at this mega-batch boundary.
    pub pool_events: Vec<PoolEventRow>,
    /// Mean true nnz per dispatched batch within this mega-batch.
    pub nnz_mean: f64,
    /// Coefficient of variation of per-batch nnz — the batch-cost
    /// dispersion the data plane's composition policy controls.
    pub nnz_cv: f64,
    /// Cumulative data-plane counters at the end of this mega-batch.
    pub pipeline: PipelineStatsRow,
    /// Calibration plane: estimated effective speed multiplier per roster
    /// device (`[calibration]`; 0 = no estimate yet or calibration off).
    pub cost_speed: Vec<f64>,
    /// Calibration plane: median relative residual of each device's
    /// estimate — the estimate's own trust signal (0 when none).
    pub cost_residual: Vec<f64>,
    /// Sparsity lever (`[slide]`): effective active-class ratio each roster
    /// device ran this mega-batch (1.0 = dense, including inactive slots).
    pub sparsity_ratio: Vec<f64>,
    /// Sparsity lever: mean active output-class count per step, per roster
    /// device (equals the class count when dense; 0 for inactive slots).
    pub active_classes: Vec<f64>,
}

/// Data-plane counters as logged per row (cumulative since run start).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineStatsRow {
    /// Batches served from a prefetch queue.
    pub prefetched: u64,
    /// Batches assembled synchronously on the consumer thread.
    pub synchronous: u64,
    /// Consumer hits on an empty prefetch queue.
    pub starved: u64,
    /// Prefetched batches flushed by reconfiguration.
    pub flushed: u64,
    /// Features dropped by `max_nnz` truncation.
    pub truncated_features: u64,
    /// Batch-buffer pool hits / misses.
    pub pool_hits: u64,
    pub pool_misses: u64,
}

/// One pool-membership change (also aggregated run-wide in
/// [`RunLog::pool_events`]).
#[derive(Clone, Debug, PartialEq)]
pub struct PoolEventRow {
    pub mega_batch: usize,
    pub device: usize,
    /// "remove" | "add" | "quarantine" | "readmit".
    pub action: String,
    pub reason: String,
}

/// One fleet lease-ownership change (grant / revoke / release /
/// force-release, plus the arbiter's preempt / return annotations) —
/// the multi-tenant analog of [`PoolEventRow`], stamped with the shared
/// fleet clock instead of a mega-batch index.
#[derive(Clone, Debug, PartialEq)]
pub struct LeaseEventRow {
    /// Fleet virtual clock (seconds) when the change landed.
    pub at: f64,
    /// Tenant holding (or receiving) the lease.
    pub tenant: usize,
    pub device: usize,
    /// "grant" | "revoke" | "release" | "force-release" | "preempt" |
    /// "return".
    pub action: String,
    pub reason: String,
}

impl LeaseEventRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at", Json::num(self.at)),
            ("tenant", Json::int(self.tenant as i64)),
            ("device", Json::int(self.device as i64)),
            ("action", Json::str(self.action.clone())),
            ("reason", Json::str(self.reason.clone())),
        ])
    }
}

/// One cross-server synchronization event from the cluster plane — the
/// inter-server analog of [`PoolEventRow`], stamped with the cluster clock
/// *and* the server's mega-batch index at the event.
#[derive(Clone, Debug, PartialEq)]
pub struct SyncEventRow {
    /// Cluster virtual clock (seconds) when the event landed.
    pub at: f64,
    /// The server's completed mega-batches at the event.
    pub mega_batch: usize,
    /// Cluster server id the event applies to.
    pub server: usize,
    /// "sync" | "demote" | "promote" | "rack-down" | "rack-up" | "cadence".
    pub action: String,
    pub reason: String,
}

impl SyncEventRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at", Json::num(self.at)),
            ("mega_batch", Json::int(self.mega_batch as i64)),
            ("server", Json::int(self.server as i64)),
            ("action", Json::str(self.action.clone())),
            ("reason", Json::str(self.reason.clone())),
        ])
    }
}

/// Per-link fabric telemetry accumulated over a cluster run (one row per
/// server uplink): exported in both the CSV and the JSON log.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkStatRow {
    /// Uplink (server) id.
    pub link: usize,
    /// Total bytes this link carried across inter-server syncs.
    pub bytes_transferred: f64,
    /// Total seconds this link spent in inter-server syncs.
    pub sync_seconds: f64,
    /// Mean staleness (mega-batches behind the sync target) the server
    /// carried into the merges it joined over this link.
    pub staleness_mb: f64,
}

impl LinkStatRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("link", Json::int(self.link as i64)),
            ("bytes_transferred", Json::num(self.bytes_transferred)),
            ("sync_seconds", Json::num(self.sync_seconds)),
            ("staleness_mb", Json::num(self.staleness_mb)),
        ])
    }
}

/// Full run log.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub name: String,
    pub rows: Vec<MegaBatchRow>,
    /// Every pool membership change over the run, in order.
    pub pool_events: Vec<PoolEventRow>,
    /// Cross-server sync events this run participated in (cluster plane;
    /// empty for single-server runs).
    pub sync_events: Vec<SyncEventRow>,
    /// Per-link fabric telemetry (cluster plane; empty for single-server
    /// runs).
    pub link_stats: Vec<LinkStatRow>,
    /// Counter-registry snapshot at the end of the run (`[obs]` plane;
    /// empty — and absent from both export formats — when obs is
    /// disabled, so pre-obs outputs stay byte-identical).
    pub metrics: Vec<crate::obs::MetricRow>,
}

impl RunLog {
    pub fn new(name: impl Into<String>) -> Self {
        RunLog {
            name: name.into(),
            rows: Vec::new(),
            pool_events: Vec::new(),
            sync_events: Vec::new(),
            link_stats: Vec::new(),
            metrics: Vec::new(),
        }
    }

    pub fn push(&mut self, row: MegaBatchRow) {
        self.rows.push(row);
    }

    /// First clock time at which accuracy >= target (time-to-accuracy).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.rows.iter().find(|r| r.accuracy >= target).map(|r| r.clock)
    }

    /// First mega-batch index reaching the target (statistical efficiency).
    pub fn megabatches_to_accuracy(&self, target: f64) -> Option<usize> {
        self.rows.iter().find(|r| r.accuracy >= target).map(|r| r.mega_batch)
    }

    pub fn best_accuracy(&self) -> f64 {
        self.rows.iter().map(|r| r.accuracy).fold(0.0, f64::max)
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rows.last().map(|r| r.accuracy).unwrap_or(0.0)
    }

    /// Active-device count per mega-batch — the pool's size trajectory
    /// (elasticity tests assert the transitions on this).
    pub fn device_counts(&self) -> Vec<usize> {
        self.rows.iter().map(|r| r.active_devices.len()).collect()
    }

    /// Fraction of merges in which perturbation activated (Fig. 12b).
    pub fn perturbation_frequency(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows.iter().filter(|r| r.perturbed).count() as f64 / self.rows.len() as f64
    }

    /// How many mega-batches had *completed* (merged) by training-clock
    /// time `t` — the serving plane's reference point for snapshot
    /// staleness (rows are clock-ordered).
    pub fn mega_batches_completed_at(&self, t: f64) -> usize {
        self.rows.partition_point(|r| r.clock <= t)
    }

    /// Test accuracy of the training run as of clock time `t` (the last
    /// evaluated row at or before `t`); NaN before the first merge — the
    /// train-while-serve comparison column.
    pub fn accuracy_at_clock(&self, t: f64) -> f64 {
        match self.rows.partition_point(|r| r.clock <= t) {
            0 => f64::NAN,
            n => self.rows[n - 1].accuracy,
        }
    }

    /// Run-average per-batch nnz coefficient of variation (the pipeline
    /// experiment's headline number).
    pub fn mean_nnz_cv(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows.iter().map(|r| r.nnz_cv).sum::<f64>() / self.rows.len() as f64
    }

    /// Run-average update balance: per row, the max/min ratio of update
    /// counts among devices that did any work (1.0 = the paper's
    /// equal-update-rate goal; rows with fewer than two working devices
    /// count as balanced). The calibration experiment's headline number —
    /// drift unbalances it, calibrated scheduling pulls it back toward 1.
    pub fn update_balance(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.window_balance(0, usize::MAX)
    }

    /// [`update_balance`](RunLog::update_balance) restricted to
    /// mega-batches `[from, to)` — how a drift window scored, without the
    /// pre-throttle and recovery rows diluting it. 1.0 when the range
    /// holds no rows. The single definition of "update balance": the
    /// calibration experiment and its tests both call this.
    pub fn window_balance(&self, from: usize, to: usize) -> f64 {
        let per_row: Vec<f64> = self
            .rows
            .iter()
            .filter(|r| (from..to).contains(&r.mega_batch))
            .map(|r| {
                let working: Vec<u64> = r.updates.iter().copied().filter(|&u| u > 0).collect();
                if working.len() < 2 {
                    1.0
                } else {
                    let hi = *working.iter().max().unwrap() as f64;
                    let lo = *working.iter().min().unwrap() as f64;
                    hi / lo
                }
            })
            .collect();
        if per_row.is_empty() {
            1.0
        } else {
            per_row.iter().sum::<f64>() / per_row.len() as f64
        }
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        let dev = self.rows.first().map(|r| r.batch_sizes.len()).unwrap_or(0);
        let mut header: Vec<String> = [
            "mega_batch",
            "clock",
            "samples",
            "loss",
            "accuracy",
            "perturbed",
            "merge_time",
            "l2_per_param",
            "nnz_mean",
            "nnz_cv",
            "starved",
            "truncated",
            "active",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        for tag in ["b", "u", "util", "est", "ratio", "act"] {
            for i in 0..dev {
                header.push(format!("{tag}{i}"));
            }
        }
        write_section(
            &mut f,
            &header,
            self.rows.iter().map(|r| {
                let mut cells = vec![
                    r.mega_batch.to_string(),
                    format!("{:.6}", r.clock),
                    r.samples.to_string(),
                    format!("{:.6}", r.loss),
                    format!("{:.6}", r.accuracy),
                    (r.perturbed as u8).to_string(),
                    format!("{:.6}", r.merge_time),
                    format!("{:.8}", r.l2_per_param),
                    format!("{:.2}", r.nnz_mean),
                    format!("{:.6}", r.nnz_cv),
                    r.pipeline.starved.to_string(),
                    r.pipeline.truncated_features.to_string(),
                    r.active_devices.len().to_string(),
                ];
                cells.extend(r.batch_sizes.iter().map(|b| b.to_string()));
                cells.extend(r.updates.iter().map(|u| u.to_string()));
                cells.extend(r.utilization.iter().map(|u| format!("{u:.4}")));
                cells.extend(r.cost_speed.iter().map(|s| format!("{s:.4}")));
                cells.extend(r.sparsity_ratio.iter().map(|s| format!("{s:.4}")));
                cells.extend(r.active_classes.iter().map(|a| format!("{a:.1}")));
                cells
            }),
        )?;
        // Cluster-plane sections (only when the run actually crossed
        // servers, so single-server CSVs stay byte-identical).
        if !self.link_stats.is_empty() {
            let header: Vec<String> = ["link", "bytes_transferred", "sync_seconds", "staleness_mb"]
                .iter()
                .map(|s| s.to_string())
                .collect();
            write_section(
                &mut f,
                &header,
                self.link_stats.iter().map(|l| {
                    vec![
                        l.link.to_string(),
                        format!("{:.0}", l.bytes_transferred),
                        format!("{:.6}", l.sync_seconds),
                        format!("{:.4}", l.staleness_mb),
                    ]
                }),
            )?;
        }
        if !self.sync_events.is_empty() {
            let header: Vec<String> = ["at", "mega_batch", "server", "action", "reason"]
                .iter()
                .map(|s| s.to_string())
                .collect();
            write_section(
                &mut f,
                &header,
                self.sync_events.iter().map(|e| {
                    vec![
                        format!("{:.6}", e.at),
                        e.mega_batch.to_string(),
                        e.server.to_string(),
                        e.action.clone(),
                        e.reason.clone(),
                    ]
                }),
            )?;
        }
        // Observability section (only when the obs plane exported a
        // registry snapshot, so pre-obs CSVs stay byte-identical).
        if !self.metrics.is_empty() {
            let header: Vec<String> =
                ["metric", "kind", "value"].iter().map(|s| s.to_string()).collect();
            write_section(
                &mut f,
                &header,
                self.metrics.iter().map(|m| {
                    vec![m.name.clone(), m.kind.to_string(), fmt_metric_value(m.value)]
                }),
            )?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::obj(vec![
                        ("mega_batch", Json::int(r.mega_batch as i64)),
                        ("clock", Json::num(r.clock)),
                        ("samples", Json::int(r.samples as i64)),
                        ("loss", Json::num(r.loss)),
                        ("accuracy", Json::num(r.accuracy)),
                        ("batch_sizes", Json::arr(r.batch_sizes.iter().map(|&b| Json::int(b as i64)))),
                        ("updates", Json::arr(r.updates.iter().map(|&u| Json::int(u as i64)))),
                        ("perturbed", Json::Bool(r.perturbed)),
                        ("utilization", Json::arr(r.utilization.iter().map(|&u| Json::num(u)))),
                        ("merge_time", Json::num(r.merge_time)),
                        ("l2_per_param", Json::num(r.l2_per_param)),
                        (
                            "active_devices",
                            Json::arr(r.active_devices.iter().map(|&d| Json::int(d as i64))),
                        ),
                        (
                            "merge_weights",
                            Json::arr(r.merge_weights.iter().map(|&w| Json::num(w))),
                        ),
                        (
                            "pool_events",
                            Json::arr(r.pool_events.iter().map(pool_event_json)),
                        ),
                        ("nnz_mean", Json::num(r.nnz_mean)),
                        ("nnz_cv", Json::num(r.nnz_cv)),
                        (
                            "cost_speed",
                            Json::arr(r.cost_speed.iter().map(|&s| Json::num(s))),
                        ),
                        (
                            "cost_residual",
                            Json::arr(r.cost_residual.iter().map(|&s| Json::num(s))),
                        ),
                        (
                            "sparsity_ratio",
                            Json::arr(r.sparsity_ratio.iter().map(|&s| Json::num(s))),
                        ),
                        (
                            "active_classes",
                            Json::arr(r.active_classes.iter().map(|&s| Json::num(s))),
                        ),
                        (
                            "pipeline",
                            Json::obj(vec![
                                ("prefetched", Json::int(r.pipeline.prefetched as i64)),
                                ("synchronous", Json::int(r.pipeline.synchronous as i64)),
                                ("starved", Json::int(r.pipeline.starved as i64)),
                                ("flushed", Json::int(r.pipeline.flushed as i64)),
                                (
                                    "truncated_features",
                                    Json::int(r.pipeline.truncated_features as i64),
                                ),
                                ("pool_hits", Json::int(r.pipeline.pool_hits as i64)),
                                ("pool_misses", Json::int(r.pipeline.pool_misses as i64)),
                            ]),
                        ),
                    ])
                })),
            ),
            (
                "pool_events",
                Json::arr(self.pool_events.iter().map(pool_event_json)),
            ),
        ];
        // Cluster-plane keys only appear when populated, so single-server
        // JSON exports stay byte-identical to the pre-cluster format.
        if !self.sync_events.is_empty() {
            pairs.push((
                "sync_events",
                Json::arr(self.sync_events.iter().map(|e| e.to_json())),
            ));
        }
        if !self.link_stats.is_empty() {
            pairs.push((
                "link_stats",
                Json::arr(self.link_stats.iter().map(|l| l.to_json())),
            ));
        }
        // Obs-plane key only appears when the registry snapshot is
        // populated, so disabled-obs JSON exports keep the pre-obs bytes.
        if !self.metrics.is_empty() {
            pairs.push((
                "metrics",
                Json::arr(self.metrics.iter().map(|m| {
                    Json::obj(vec![
                        ("name", Json::str(m.name.clone())),
                        ("kind", Json::str(m.kind)),
                        ("value", Json::num(m.value)),
                    ])
                })),
            ));
        }
        Json::obj(pairs)
    }

    pub fn write_json(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

fn pool_event_json(ev: &PoolEventRow) -> Json {
    Json::obj(vec![
        ("mega_batch", Json::int(ev.mega_batch as i64)),
        ("device", Json::int(ev.device as i64)),
        ("action", Json::str(ev.action.clone())),
        ("reason", Json::str(ev.reason.clone())),
    ])
}

/// Write one CSV section: a header line followed by data rows, every row
/// asserted to match the header's arity and every cell escaped. All
/// current exports contain no comma/quote/newline cells, so escaping is
/// a no-op on them and the bytes stay identical to the pre-section
/// writer; it only kicks in for free-form reason strings.
fn write_section<W: Write>(
    f: &mut W,
    header: &[String],
    rows: impl Iterator<Item = Vec<String>>,
) -> Result<()> {
    let join = |cells: &[String]| {
        cells.iter().map(|c| csv_escape(c)).collect::<Vec<_>>().join(",")
    };
    writeln!(f, "{}", join(header))?;
    for cells in rows {
        assert_eq!(
            cells.len(),
            header.len(),
            "CSV row arity mismatch in section starting {:?}",
            header.first()
        );
        writeln!(f, "{}", join(&cells))?;
    }
    Ok(())
}

/// RFC-4180-style field escape: quote (doubling inner quotes) only when
/// the field contains a comma, quote, or newline; all other fields pass
/// through unchanged so existing numeric exports keep their exact bytes.
pub fn csv_escape(field: &str) -> String {
    if field.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Parse one CSV line produced by [`csv_escape`]-joined cells back into
/// fields (handles quoted fields and doubled inner quotes). The inverse
/// half of the export round-trip test.
pub fn parse_csv_line(line: &str) -> Vec<String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            } else {
                cur.push(c);
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => fields.push(std::mem::take(&mut cur)),
                _ => cur.push(c),
            }
        }
    }
    fields.push(cur);
    fields
}

/// Metric values print as integers when whole (counters, histogram
/// counts) and with six decimals otherwise (sums, gauges) — compact and
/// deterministic.
fn fmt_metric_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{v:.0}")
    } else {
        format!("{v:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(mb: usize, clock: f64, acc: f64, perturbed: bool) -> MegaBatchRow {
        MegaBatchRow {
            mega_batch: mb,
            clock,
            samples: (mb as u64 + 1) * 1000,
            loss: 5.0 - acc,
            accuracy: acc,
            batch_sizes: vec![128, 96],
            updates: vec![10, 8],
            perturbed,
            merge_time: 0.01,
            l2_per_param: 0.05,
            utilization: vec![0.98, 0.80],
            active_devices: vec![0, 1],
            merge_weights: vec![0.55, 0.45],
            pool_events: Vec::new(),
            nnz_mean: 1536.0,
            nnz_cv: 0.12,
            pipeline: PipelineStatsRow {
                prefetched: 14,
                synchronous: 4,
                starved: 1,
                flushed: 0,
                truncated_features: 3,
                pool_hits: 16,
                pool_misses: 2,
            },
            cost_speed: vec![1.02, 1.34],
            cost_residual: vec![0.01, 0.02],
            sparsity_ratio: vec![1.0, 0.5],
            active_classes: vec![1024.0, 560.0],
        }
    }

    #[test]
    fn tta_and_statistical_efficiency() {
        let mut log = RunLog::new("t");
        log.push(row(0, 1.0, 0.10, false));
        log.push(row(1, 2.0, 0.25, true));
        log.push(row(2, 3.0, 0.32, true));
        assert_eq!(log.time_to_accuracy(0.2), Some(2.0));
        assert_eq!(log.megabatches_to_accuracy(0.2), Some(1));
        assert_eq!(log.time_to_accuracy(0.9), None);
        assert!((log.best_accuracy() - 0.32).abs() < 1e-12);
        assert!((log.perturbation_frequency() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(log.device_counts(), vec![2, 2, 2]);
        assert!((log.mean_nnz_cv() - 0.12).abs() < 1e-12);
    }

    #[test]
    fn clock_lookups_for_the_serving_plane() {
        let mut log = RunLog::new("t");
        log.push(row(0, 1.0, 0.10, false));
        log.push(row(1, 2.0, 0.25, false));
        log.push(row(2, 3.0, 0.32, false));
        assert_eq!(log.mega_batches_completed_at(0.5), 0);
        assert_eq!(log.mega_batches_completed_at(1.0), 1);
        assert_eq!(log.mega_batches_completed_at(2.7), 2);
        assert_eq!(log.mega_batches_completed_at(99.0), 3);
        assert!(log.accuracy_at_clock(0.5).is_nan());
        assert_eq!(log.accuracy_at_clock(2.0), 0.25);
        assert_eq!(log.accuracy_at_clock(99.0), 0.32);
    }

    #[test]
    fn csv_export_shape() {
        let mut log = RunLog::new("t");
        log.push(row(0, 1.0, 0.1, false));
        let path = std::env::temp_dir().join("hs-metrics-test.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("mega_batch,clock"));
        assert!(lines[0].contains(",active,"));
        assert!(lines[0].contains(",nnz_mean,nnz_cv,starved,truncated,"));
        assert!(lines[0]
            .ends_with("b0,b1,u0,u1,util0,util1,est0,est1,ratio0,ratio1,act0,act1"));
        assert_eq!(lines[1].split(',').count(), lines[0].split(',').count());
    }

    #[test]
    fn update_balance_ratios_working_devices_only() {
        let mut log = RunLog::new("t");
        assert_eq!(log.update_balance(), 0.0, "empty log");
        let mut r = row(0, 1.0, 0.1, false);
        r.updates = vec![12, 6];
        log.push(r);
        let mut r = row(1, 2.0, 0.2, false);
        r.updates = vec![10, 0]; // inactive device doesn't skew the ratio
        log.push(r);
        assert!((log.update_balance() - (2.0 + 1.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn cluster_rows_export_and_stay_absent_when_empty() {
        let mut log = RunLog::new("c");
        log.push(row(0, 1.0, 0.1, false));
        // Single-server: no cluster keys/sections in either format.
        let j = Json::parse(&log.to_json().to_string()).unwrap();
        assert!(j.as_obj().unwrap().get("sync_events").is_none());
        assert!(j.as_obj().unwrap().get("link_stats").is_none());
        let path = std::env::temp_dir().join("hs-metrics-cluster-empty.csv");
        log.write_csv(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap().lines().count(), 2);

        log.sync_events.push(SyncEventRow {
            at: 3.5,
            mega_batch: 4,
            server: 1,
            action: "sync".to_string(),
            reason: "cadence=4".to_string(),
        });
        log.link_stats.push(LinkStatRow {
            link: 1,
            bytes_transferred: 2.3e6,
            sync_seconds: 0.04,
            staleness_mb: 0.5,
        });
        let j = Json::parse(&log.to_json().to_string()).unwrap();
        let evs = j.get("sync_events").as_arr().unwrap();
        assert_eq!(evs[0].get("server").as_i64(), Some(1));
        assert_eq!(evs[0].get("action").as_str(), Some("sync"));
        let links = j.get("link_stats").as_arr().unwrap();
        assert_eq!(links[0].get("link").as_i64(), Some(1));
        assert!(links[0].get("bytes_transferred").as_f64().unwrap() > 1e6);
        let path = std::env::temp_dir().join("hs-metrics-cluster.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("link,bytes_transferred,sync_seconds,staleness_mb"));
        assert!(text.contains("at,mega_batch,server,action,reason"));
        assert!(text.contains(",sync,cadence=4"));
    }

    #[test]
    fn csv_escape_quotes_only_when_needed() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("3.14"), "3.14");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_escape("two\nlines"), "\"two\nlines\"");
    }

    #[test]
    fn csv_line_round_trips_through_escape_and_parse() {
        let fields = vec![
            "plain".to_string(),
            "with,comma".to_string(),
            "with \"quotes\"".to_string(),
            "both, \"of\" them".to_string(),
            "".to_string(),
        ];
        let line = fields.iter().map(|f| csv_escape(f)).collect::<Vec<_>>().join(",");
        assert_eq!(parse_csv_line(&line), fields);
        // Unescaped numeric lines parse too (the common case).
        assert_eq!(
            parse_csv_line("0,1.000000,1000"),
            vec!["0".to_string(), "1.000000".to_string(), "1000".to_string()]
        );
    }

    #[test]
    fn sync_event_reasons_with_commas_survive_the_csv() {
        let mut log = RunLog::new("c");
        log.push(row(0, 1.0, 0.1, false));
        log.sync_events.push(SyncEventRow {
            at: 2.0,
            mega_batch: 3,
            server: 0,
            action: "cadence".to_string(),
            reason: "stale=2, budget=0.5".to_string(),
        });
        let path = std::env::temp_dir().join("hs-metrics-escape.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let line = text.lines().last().unwrap();
        assert!(line.ends_with("\"stale=2, budget=0.5\""));
        let fields = parse_csv_line(line);
        assert_eq!(fields.len(), 5);
        assert_eq!(fields[4], "stale=2, budget=0.5");
    }

    #[test]
    fn metrics_section_exports_and_stays_absent_when_empty() {
        let mut log = RunLog::new("m");
        log.push(row(0, 1.0, 0.1, false));
        let path = std::env::temp_dir().join("hs-metrics-obs.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.contains("metric,kind,value"));
        let j = Json::parse(&log.to_json().to_string()).unwrap();
        assert!(j.as_obj().unwrap().get("metrics").is_none());

        log.metrics.push(crate::obs::MetricRow {
            name: "train.mega_batches".to_string(),
            kind: "counter",
            value: 14.0,
        });
        log.metrics.push(crate::obs::MetricRow {
            name: "serve.latency.sum".to_string(),
            kind: "histogram",
            value: 0.25,
        });
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("metric,kind,value\n"));
        assert!(text.contains("train.mega_batches,counter,14\n"));
        assert!(text.contains("serve.latency.sum,histogram,0.250000\n"));
        let j = Json::parse(&log.to_json().to_string()).unwrap();
        let rows = j.get("metrics").as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").as_str(), Some("train.mega_batches"));
        assert_eq!(rows[0].get("value").as_f64(), Some(14.0));
    }

    #[test]
    #[should_panic(expected = "arity mismatch")]
    fn csv_sections_assert_header_row_arity() {
        let mut log = RunLog::new("bad");
        log.push(row(0, 1.0, 0.1, false));
        let mut bad = row(1, 2.0, 0.2, false);
        bad.batch_sizes.push(64); // wider than the header derived from row 0
        log.push(bad);
        let path = std::env::temp_dir().join("hs-metrics-arity.csv");
        let _ = log.write_csv(&path);
    }

    #[test]
    fn json_round_trips_with_pool_events() {
        let mut log = RunLog::new("t");
        let mut r = row(0, 1.5, 0.2, true);
        r.pool_events.push(PoolEventRow {
            mega_batch: 0,
            device: 1,
            action: "quarantine".to_string(),
            reason: "test".to_string(),
        });
        log.pool_events.push(r.pool_events[0].clone());
        log.push(r);
        let j = log.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("name").as_str(), Some("t"));
        assert_eq!(parsed.get("rows").as_arr().unwrap().len(), 1);
        let events = parsed.get("pool_events").as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("action").as_str(), Some("quarantine"));
        assert_eq!(events[0].get("device").as_i64(), Some(1));
        let row0 = &parsed.get("rows").as_arr().unwrap()[0];
        assert_eq!(row0.get("active_devices").as_arr().unwrap().len(), 2);
        assert_eq!(row0.get("pool_events").as_arr().unwrap().len(), 1);
        assert!(row0.get("nnz_cv").as_f64().unwrap() > 0.0);
        let pipeline = row0.get("pipeline");
        assert_eq!(pipeline.get("prefetched").as_i64(), Some(14));
        assert_eq!(pipeline.get("starved").as_i64(), Some(1));
        assert_eq!(pipeline.get("pool_hits").as_i64(), Some(16));
        assert_eq!(row0.get("cost_speed").as_arr().unwrap().len(), 2);
        assert_eq!(row0.get("cost_residual").as_arr().unwrap().len(), 2);
        assert_eq!(row0.get("sparsity_ratio").as_arr().unwrap().len(), 2);
        assert_eq!(row0.get("sparsity_ratio").as_arr().unwrap()[1].as_f64(), Some(0.5));
        assert_eq!(row0.get("active_classes").as_arr().unwrap().len(), 2);
    }
}

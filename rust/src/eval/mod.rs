//! Test-set evaluation: top-1 accuracy (P@1) — the paper's accuracy metric.
//!
//! A prediction is correct when the argmax class is *any* of the sample's
//! true labels (standard XML P@1). Evaluation runs through the same backend
//! abstraction as training, so it uses the AOT eval executable under PJRT
//! and the pure-Rust forward pass in hermetic tests.

use crate::coordinator::backend::StepBackend;
use crate::data::batcher::EvalBatches;
use crate::data::SparseDataset;
use crate::model::ModelState;
use crate::Result;

/// P@1 over the prepared eval batches.
pub fn p_at_1(
    backend: &dyn StepBackend,
    model: &ModelState,
    eval: &EvalBatches,
    test: &SparseDataset,
) -> Result<f64> {
    let mut hit = 0usize;
    let mut total = 0usize;
    for batch in &eval.batches {
        let preds = backend.eval(model, batch)?;
        for (r, &id) in batch.sample_ids.iter().enumerate() {
            total += 1;
            let labels = test.sample(id as usize).labels;
            if labels.contains(&(preds[r].max(0) as u32)) {
                hit += 1;
            }
        }
    }
    Ok(if total == 0 { 0.0 } else { hit as f64 / total as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::coordinator::backend::RefBackend;
    use crate::data::synthetic::Generator;

    #[test]
    fn random_model_scores_near_chance_and_oracle_labels_work() {
        let dims = ModelDims { features: 128, hidden: 8, classes: 50, max_nnz: 8, max_labels: 4 };
        let cfg = DataConfig { test_samples: 300, ..Default::default() };
        let test = Generator::new(&dims, &cfg).generate(300, 2);
        let eval = EvalBatches::new(&test, &dims, 64);
        let backend = RefBackend;
        let model = ModelState::init(&dims, 3);
        let acc = p_at_1(&backend, &model, &eval, &test).unwrap();
        // Random model on 50 classes with ~2 labels/sample: expect well
        // below 0.35 but >= 0 (popular-class bias allowed).
        assert!((0.0..0.35).contains(&acc), "acc={acc}");
    }
}

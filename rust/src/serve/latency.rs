//! Windowed serving telemetry: latency percentiles, throughput, queue
//! depth, batch fill, and served-snapshot staleness.
//!
//! The replay loop records raw per-request and per-batch events; this
//! module folds them into fixed-length windows after the fact — windowing
//! by *completion* time for latency/throughput and by *arrival* time for
//! admission load, so a batch finishing after its window's arrivals lands
//! where an operator's dashboard would put it. Per-window latency
//! percentiles come from [`crate::util::stats::trailing_percentile`] — the
//! same definition the fleet arbiter's SLO-breach detector uses, so
//! telemetry and the arbiter can never disagree on what a p95 breach
//! means — and yield NaN for an empty window (zero completed requests is a
//! normal state during bursts' quiet phases, not an error).

use crate::metrics::{PoolEventRow, RunLog};
use crate::util::json::Json;
use crate::util::stats;

/// One completed request (times in virtual seconds from trace start).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival: f64,
    pub completion: f64,
    /// Did the served model's top-1 prediction hit a true label?
    pub hit: bool,
}

/// One served micro-batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchRecord {
    pub formed_at: f64,
    pub start: f64,
    pub completion: f64,
    pub device: usize,
    pub bucket: usize,
    pub valid: usize,
    /// Snapshot version the batch was served from.
    pub version: u64,
    /// Served-snapshot staleness in mega-batches at formation time (None
    /// without a training timeline, e.g. checkpoint-only serving).
    pub staleness: Option<usize>,
}

/// One telemetry window.
#[derive(Clone, Debug)]
pub struct ServeWindow {
    pub window: usize,
    pub start: f64,
    pub end: f64,
    /// Requests that *arrived* in the window.
    pub admitted: u64,
    /// Requests that *completed* in the window.
    pub completed: u64,
    pub batches: u64,
    /// Latency percentiles in milliseconds (NaN when nothing completed).
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Completions per second.
    pub throughput: f64,
    /// Peak admission queue depth observed in the window.
    pub max_queue_depth: usize,
    /// Mean valid/bucket of the window's batches (NaN without batches).
    pub mean_fill: f64,
    /// Mean staleness in mega-batches (NaN without a training timeline).
    pub mean_staleness: f64,
    /// P@1 over the window's served requests (NaN when nothing completed).
    pub served_accuracy: f64,
    /// Training-curve accuracy at the window end (NaN without a timeline).
    pub train_accuracy: f64,
    /// Snapshot versions served in the window (0/0 when idle).
    pub min_version: u64,
    pub max_version: u64,
}

/// Full serving-run telemetry.
#[derive(Clone, Debug, Default)]
pub struct ServeLog {
    pub name: String,
    pub rows: Vec<ServeWindow>,
    pub requests: Vec<RequestRecord>,
    pub batches: Vec<BatchRecord>,
    /// Serving-pool membership changes (window-indexed).
    pub pool_events: Vec<PoolEventRow>,
    /// Nominal trace duration in seconds (completions may run past it).
    pub duration: f64,
}

impl ServeLog {
    /// Fold raw records into windows of `window_secs`. `depth_samples` are
    /// (time, queue depth) observations; `train_log` enables the staleness
    /// and training-accuracy columns.
    #[allow(clippy::too_many_arguments)]
    pub fn summarize(
        name: impl Into<String>,
        duration: f64,
        window_secs: f64,
        requests: Vec<RequestRecord>,
        batches: Vec<BatchRecord>,
        depth_samples: &[(f64, usize)],
        pool_events: Vec<PoolEventRow>,
        train_log: Option<&RunLog>,
    ) -> ServeLog {
        assert!(window_secs > 0.0);
        let horizon = requests
            .iter()
            .map(|r| r.completion)
            .fold(duration, f64::max);
        let windows = (horizon / window_secs).ceil().max(1.0) as usize;
        let idx = |t: f64| ((t / window_secs) as usize).min(windows - 1);

        let mut rows: Vec<ServeWindow> = (0..windows)
            .map(|w| ServeWindow {
                window: w,
                start: w as f64 * window_secs,
                end: (w + 1) as f64 * window_secs,
                admitted: 0,
                completed: 0,
                batches: 0,
                p50_ms: f64::NAN,
                p95_ms: f64::NAN,
                p99_ms: f64::NAN,
                throughput: 0.0,
                max_queue_depth: 0,
                mean_fill: f64::NAN,
                mean_staleness: f64::NAN,
                served_accuracy: f64::NAN,
                train_accuracy: train_log
                    .map(|l| l.accuracy_at_clock((w + 1) as f64 * window_secs))
                    .unwrap_or(f64::NAN),
                min_version: 0,
                max_version: 0,
            })
            .collect();

        // (completion time, latency ms) events for the shared windowed-
        // quantile helper. Completion-windowed metrics (completed, hits,
        // throughput, percentiles) all use the helper's trailing
        // `(start, end]` convention — end-inclusive, so a completion landing
        // exactly on a boundary belongs to the window that closes there and
        // a row's percentiles cover exactly the requests its `completed`
        // counts. Arrival/batch bucketing keeps the plain `[start, end)`
        // grid (no percentile counterpart to disagree with).
        let mut lat_events: Vec<(f64, f64)> =
            requests.iter().map(|r| (r.completion, (r.completion - r.arrival) * 1e3)).collect();
        lat_events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let cidx = |t: f64| {
            if t <= 0.0 {
                0
            } else {
                ((t / window_secs).ceil() as usize).saturating_sub(1).min(windows - 1)
            }
        };
        let mut hits = vec![0u64; windows];
        for r in &requests {
            rows[idx(r.arrival)].admitted += 1;
            let w = cidx(r.completion);
            rows[w].completed += 1;
            hits[w] += r.hit as u64;
        }
        let mut fills: Vec<Vec<f64>> = vec![Vec::new(); windows];
        let mut stale: Vec<Vec<f64>> = vec![Vec::new(); windows];
        for b in &batches {
            let w = idx(b.completion);
            let row = &mut rows[w];
            row.batches += 1;
            if row.min_version == 0 || b.version < row.min_version {
                row.min_version = b.version;
            }
            row.max_version = row.max_version.max(b.version);
            fills[w].push(b.valid as f64 / b.bucket as f64);
            if let Some(s) = b.staleness {
                stale[w].push(s as f64);
            }
        }
        for (t, depth) in depth_samples {
            let row = &mut rows[idx(*t)];
            row.max_queue_depth = row.max_queue_depth.max(*depth);
        }
        for (w, row) in rows.iter_mut().enumerate() {
            let end = row.end;
            row.p50_ms = stats::trailing_percentile_sorted(&lat_events, end, window_secs, 50.0);
            row.p95_ms = stats::trailing_percentile_sorted(&lat_events, end, window_secs, 95.0);
            row.p99_ms = stats::trailing_percentile_sorted(&lat_events, end, window_secs, 99.0);
            row.throughput = row.completed as f64 / window_secs;
            if row.completed > 0 {
                row.served_accuracy = hits[w] as f64 / row.completed as f64;
            }
            if !fills[w].is_empty() {
                row.mean_fill = stats::mean(&fills[w]);
            }
            if !stale[w].is_empty() {
                row.mean_staleness = stats::mean(&stale[w]);
            }
        }
        ServeLog { name: name.into(), rows, requests, batches, pool_events, duration }
    }

    pub fn total_requests(&self) -> usize {
        self.requests.len()
    }

    /// Run-wide latency percentile in milliseconds (NaN when empty).
    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        let lat: Vec<f64> =
            self.requests.iter().map(|r| (r.completion - r.arrival) * 1e3).collect();
        stats::percentile(&lat, p)
    }

    /// Run-wide *delivered* throughput: completions that landed inside the
    /// nominal duration, per second. Under overload the backlog drains
    /// after the trace ends, so this sinks below the offered rate instead
    /// of parroting it.
    pub fn throughput(&self) -> f64 {
        self.requests.iter().filter(|r| r.completion <= self.duration).count() as f64
            / self.duration
    }

    /// Run-wide served P@1 (NaN when nothing completed).
    pub fn served_accuracy(&self) -> f64 {
        if self.requests.is_empty() {
            return f64::NAN;
        }
        self.requests.iter().filter(|r| r.hit).count() as f64 / self.requests.len() as f64
    }

    /// Run-wide mean staleness in mega-batches (NaN without a timeline).
    pub fn mean_staleness(&self) -> f64 {
        let s: Vec<f64> =
            self.batches.iter().filter_map(|b| b.staleness.map(|x| x as f64)).collect();
        if s.is_empty() {
            f64::NAN
        } else {
            stats::mean(&s)
        }
    }

    pub fn max_queue_depth(&self) -> usize {
        self.rows.iter().map(|r| r.max_queue_depth).max().unwrap_or(0)
    }

    /// JSON export (window rows + run-wide summary; raw per-request records
    /// stay in memory only). NaN telemetry (empty windows) exports as
    /// `null` — "NaN" is not valid JSON.
    pub fn to_json(&self) -> Json {
        fn num(x: f64) -> Json {
            if x.is_finite() {
                Json::num(x)
            } else {
                Json::Null
            }
        }
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("duration", num(self.duration)),
            ("requests", Json::int(self.total_requests() as i64)),
            ("p50_ms", num(self.latency_percentile_ms(50.0))),
            ("p95_ms", num(self.latency_percentile_ms(95.0))),
            ("p99_ms", num(self.latency_percentile_ms(99.0))),
            ("throughput_rps", num(self.throughput())),
            ("served_accuracy", num(self.served_accuracy())),
            ("mean_staleness_mb", num(self.mean_staleness())),
            (
                "windows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::obj(vec![
                        ("window", Json::int(r.window as i64)),
                        ("admitted", Json::int(r.admitted as i64)),
                        ("completed", Json::int(r.completed as i64)),
                        ("batches", Json::int(r.batches as i64)),
                        ("p50_ms", num(r.p50_ms)),
                        ("p95_ms", num(r.p95_ms)),
                        ("p99_ms", num(r.p99_ms)),
                        ("throughput_rps", num(r.throughput)),
                        ("max_queue_depth", Json::int(r.max_queue_depth as i64)),
                        ("mean_fill", num(r.mean_fill)),
                        ("mean_staleness_mb", num(r.mean_staleness)),
                        ("served_accuracy", num(r.served_accuracy)),
                        ("train_accuracy", num(r.train_accuracy)),
                    ])
                })),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, completion: f64, hit: bool) -> RequestRecord {
        RequestRecord { id, arrival, completion, hit }
    }

    fn batch(formed_at: f64, completion: f64, valid: usize, version: u64) -> BatchRecord {
        BatchRecord {
            formed_at,
            start: formed_at,
            completion,
            device: 0,
            bucket: 16,
            valid,
            version,
            staleness: Some(1),
        }
    }

    #[test]
    fn windows_split_by_completion_and_empty_windows_are_nan() {
        let requests = vec![
            req(0, 0.01, 0.02, true),
            req(1, 0.02, 0.04, false),
            // Nothing completes in window 1 (0.25..0.5).
            req(2, 0.24, 0.55, true),
        ];
        let batches = vec![batch(0.01, 0.02, 8, 1), batch(0.24, 0.55, 4, 2)];
        let log = ServeLog::summarize(
            "t",
            0.75,
            0.25,
            requests,
            batches,
            &[(0.01, 3), (0.26, 9)],
            Vec::new(),
            None,
        );
        assert_eq!(log.rows.len(), 3);
        assert_eq!(log.rows[0].completed, 2);
        assert_eq!(log.rows[0].admitted, 2);
        assert!(log.rows[0].p50_ms > 0.0);
        assert_eq!(log.rows[0].served_accuracy, 0.5);
        assert_eq!(log.rows[0].min_version, 1);
        // Window 1: one arrival, zero completions — NaN percentiles, not a
        // panic (the satellite fix this subsystem depends on).
        assert_eq!(log.rows[1].admitted, 1);
        assert_eq!(log.rows[1].completed, 0);
        assert!(log.rows[1].p99_ms.is_nan());
        assert!(log.rows[1].served_accuracy.is_nan());
        assert_eq!(log.rows[1].max_queue_depth, 9);
        // Window 2 catches the late completion.
        assert_eq!(log.rows[2].completed, 1);
        assert_eq!(log.rows[2].max_version, 2);
        assert!((log.rows[2].mean_fill - 0.25).abs() < 1e-12);
        // Run-wide summary.
        assert_eq!(log.total_requests(), 3);
        assert!((log.served_accuracy() - 2.0 / 3.0).abs() < 1e-12);
        assert!((log.mean_staleness() - 1.0).abs() < 1e-12);
        assert_eq!(log.max_queue_depth(), 9);
        assert!(log.latency_percentile_ms(99.0) > 0.0);
    }

    #[test]
    fn horizon_extends_past_the_nominal_duration() {
        let requests = vec![req(0, 0.1, 1.4, true)];
        let log = ServeLog::summarize(
            "t",
            0.5,
            0.25,
            requests,
            Vec::new(),
            &[],
            Vec::new(),
            None,
        );
        // 1.4s completion stretches the window set to 6 windows.
        assert_eq!(log.rows.len(), 6);
        assert_eq!(log.rows[5].completed, 1);
        // Delivered throughput excludes the completion past the nominal
        // duration — overload shows up instead of echoing the offered rate.
        assert_eq!(log.throughput(), 0.0);
    }

    #[test]
    fn json_exports_summary_and_windows() {
        let log = ServeLog::summarize(
            "t",
            0.25,
            0.25,
            vec![req(0, 0.0, 0.01, true)],
            Vec::new(),
            &[],
            Vec::new(),
            None,
        );
        let parsed = Json::parse(&log.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("requests").as_i64(), Some(1));
        assert_eq!(parsed.get("windows").as_arr().unwrap().len(), 1);
        assert!(parsed.get("p99_ms").as_f64().unwrap() > 0.0);
    }
}

//! Open-loop synthetic serving workload: arrival-time generation (Poisson
//! and bursty modulated-Poisson) plus request-content sampling with a
//! heavy-tail bias driven by the shard manifests.
//!
//! Arrivals are *open-loop*: the trace is generated up front from the
//! configured rate and does not react to serving latency — the standard
//! way to expose queueing behavior (a closed loop would self-throttle and
//! hide overload). Generation uses Lewis–Shedler thinning at the peak
//! rate, so both patterns share one code path and one RNG stream.
//!
//! Request *content* is a sample drawn from the serving corpus. With
//! `[serve] nnz_bias = 0` the draw follows the corpus distribution; with a
//! positive bias, shards are weighted by their manifest nnz histograms
//! (`Σ count·(2^bucket)^bias`) and samples within a shard by rejection on
//! `(nnz/shard_max)^bias` — a heavy-tailed request mix without touching
//! the corpus itself.

use crate::config::{ServeConfig, ServePattern};
use crate::data::pipeline::ShardedDataset;
use crate::util::rng::Rng;

/// One request arrival of the generated trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    /// Arrival time in virtual seconds from trace start.
    pub at: f64,
    /// Corpus sample carrying the request's features (global id).
    pub sample_id: u32,
}

/// Generate the arrival trace for `pattern` over `[0, duration)`.
/// Deterministic for a given (config, corpus, seed).
pub fn generate(
    pattern: ServePattern,
    cfg: &ServeConfig,
    data: &ShardedDataset,
    duration: f64,
    seed: u64,
) -> Vec<Arrival> {
    let mut rng = Rng::new(seed);
    let sampler = NnzBiasedSampler::new(data, cfg.nnz_bias);
    let peak = match pattern {
        ServePattern::Poisson => cfg.rate,
        ServePattern::Bursty => cfg.rate * cfg.burst_factor,
    };
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        // Exponential inter-arrival at the peak rate, thinned to r(t)/peak.
        t += -(1.0 - rng.f64()).ln() / peak;
        if t >= duration {
            break;
        }
        let r_t = match pattern {
            ServePattern::Poisson => cfg.rate,
            ServePattern::Bursty => {
                let phase = (t / cfg.burst_period).fract();
                if phase < cfg.burst_fraction {
                    cfg.rate * cfg.burst_factor
                } else {
                    cfg.rate
                }
            }
        };
        if rng.f64() < r_t / peak {
            out.push(Arrival { at: t, sample_id: sampler.draw(data, &mut rng) });
        }
    }
    out
}

/// Shard-manifest-driven sample selector: shard choice by histogram
/// weight, within-shard choice by nnz rejection (uniform when bias = 0).
struct NnzBiasedSampler {
    /// Cumulative shard-selection distribution.
    cdf: Vec<f64>,
    /// Global sample id of each shard's first sample.
    starts: Vec<usize>,
    /// Per-shard max nnz (rejection normalizer).
    shard_max: Vec<usize>,
    bias: f64,
}

impl NnzBiasedSampler {
    fn new(data: &ShardedDataset, bias: f64) -> NnzBiasedSampler {
        let manifest = data.manifest();
        let mut cdf = Vec::with_capacity(manifest.len());
        let mut starts = Vec::with_capacity(manifest.len());
        let mut shard_max = Vec::with_capacity(manifest.len());
        let mut acc = 0.0f64;
        let mut start = 0usize;
        for meta in manifest {
            let w: f64 = meta
                .nnz_hist
                .iter()
                .enumerate()
                .map(|(b, &count)| count as f64 * ((1u64 << b) as f64).powf(bias))
                .sum();
            acc += w.max(f64::MIN_POSITIVE);
            cdf.push(acc);
            starts.push(start);
            start += meta.samples;
            shard_max.push(meta.max_nnz);
        }
        for v in &mut cdf {
            *v /= acc;
        }
        NnzBiasedSampler { cdf, starts, shard_max, bias }
    }

    fn draw(&self, data: &ShardedDataset, rng: &mut Rng) -> u32 {
        let u = rng.f64();
        let shard = self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1);
        let len = data.shard(shard).len();
        let max = self.shard_max[shard].max(1) as f64;
        // Rejection on (nnz/max)^bias; bounded tries so a pathological
        // shard (all tiny samples) still terminates.
        for _ in 0..64 {
            let off = rng.range(0, len);
            if self.bias == 0.0 {
                return (self.starts[shard] + off) as u32;
            }
            let nnz = data.shard(shard).nnz(off).max(1) as f64;
            if rng.f64() < (nnz / max).powf(self.bias) {
                return (self.starts[shard] + off) as u32;
            }
        }
        (self.starts[shard] + rng.range(0, len)) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::data::synthetic::Generator;
    use std::sync::Arc;

    fn corpus(n: usize) -> Arc<ShardedDataset> {
        let dims = ModelDims { features: 256, hidden: 8, classes: 32, max_nnz: 24, max_labels: 4 };
        let cfg =
            DataConfig { train_samples: n, avg_nnz: 8.0, nnz_sigma: 0.9, ..Default::default() };
        let ds = Generator::new(&dims, &cfg).generate(n, 1);
        Arc::new(ShardedDataset::from_dataset(&ds, 128))
    }

    fn serve_cfg(rate: f64) -> ServeConfig {
        ServeConfig { rate, ..Default::default() }
    }

    #[test]
    fn poisson_trace_is_deterministic_and_hits_the_rate() {
        let data = corpus(500);
        let cfg = serve_cfg(2_000.0);
        let a = generate(ServePattern::Poisson, &cfg, &data, 4.0, 7);
        let b = generate(ServePattern::Poisson, &cfg, &data, 4.0, 7);
        assert_eq!(a, b, "same seed must reproduce the trace bit-for-bit");
        let c = generate(ServePattern::Poisson, &cfg, &data, 4.0, 8);
        assert_ne!(a, c, "different seeds must diverge");
        // Mean rate within 10% of nominal over 8k expected arrivals.
        let observed = a.len() as f64 / 4.0;
        assert!((observed / 2_000.0 - 1.0).abs() < 0.1, "rate {observed}");
        // Ordered, in-range, valid ids.
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(a.iter().all(|r| r.at < 4.0 && (r.sample_id as usize) < data.len()));
    }

    #[test]
    fn bursty_trace_is_burstier_than_poisson() {
        let data = corpus(500);
        let cfg = ServeConfig {
            rate: 2_000.0,
            burst_factor: 8.0,
            burst_period: 0.5,
            burst_fraction: 0.2,
            ..Default::default()
        };
        let peak_to_mean = |arrivals: &[Arrival]| {
            // 50ms-bin histogram over 4s.
            let mut bins = vec![0usize; 80];
            for a in arrivals {
                bins[((a.at / 0.05) as usize).min(79)] += 1;
            }
            let mean = arrivals.len() as f64 / 80.0;
            bins.iter().copied().max().unwrap() as f64 / mean
        };
        let poisson = generate(ServePattern::Poisson, &cfg, &data, 4.0, 11);
        let bursty = generate(ServePattern::Bursty, &cfg, &data, 4.0, 11);
        assert!(
            bursty.len() > poisson.len(),
            "bursts add load: {} vs {}",
            bursty.len(),
            poisson.len()
        );
        assert!(
            peak_to_mean(&bursty) > peak_to_mean(&poisson) * 1.5,
            "bursty peak/mean {:.2} must dominate poisson {:.2}",
            peak_to_mean(&bursty),
            peak_to_mean(&poisson)
        );
    }

    #[test]
    fn nnz_bias_tilts_requests_toward_heavy_samples() {
        let data = corpus(2_000);
        let mean_nnz = |arrivals: &[Arrival]| {
            arrivals.iter().map(|a| data.nnz(a.sample_id as usize) as f64).sum::<f64>()
                / arrivals.len() as f64
        };
        let flat =
            generate(ServePattern::Poisson, &serve_cfg(4_000.0), &data, 2.0, 3);
        let biased_cfg = ServeConfig { rate: 4_000.0, nnz_bias: 2.0, ..Default::default() };
        let biased = generate(ServePattern::Poisson, &biased_cfg, &data, 2.0, 3);
        assert!(
            mean_nnz(&biased) > mean_nnz(&flat) * 1.15,
            "bias must raise request nnz: {:.2} vs {:.2}",
            mean_nnz(&biased),
            mean_nnz(&flat)
        );
    }
}

//! Versioned registry of immutable model snapshots with atomic hot-swap —
//! the seam between the trainer (publisher) and the serving plane
//! (reader).
//!
//! A snapshot is an `Arc<ModelState>`: once published it is immutable, so
//! a reader that has cloned the `Arc` can never observe a torn or
//! half-written model regardless of how many publishes race past it —
//! hot-swap replaces the *pointer*, never the parameters. The trainer
//! pushes the merged global model here at mega-batch boundaries
//! (`TrainerOptions::publish`, cadence `[serve] publish_every`), and the
//! registry can also seed itself from `model::checkpoint` files, so
//! `--resume`-style artifacts become servable without a training run.
//!
//! The full publish history is retained (bounded by
//! [`SnapshotRegistry::with_history_cap`]) because train-while-serve
//! replay needs to answer "which snapshot was live at training-clock `t`"
//! ([`SnapshotRegistry::snapshot_at_clock`]).

use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::model::ModelState;
use crate::Result;

/// One published, immutable model version.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Monotone publish counter (1-based; 0 means "nothing published").
    pub version: u64,
    /// Mega-batch whose merge produced this model (None for checkpoint
    /// loads and the pre-training init publish).
    pub mega_batch: Option<usize>,
    /// Training clock at publish time (-1.0 for checkpoint loads, so they
    /// order before any training-time publish).
    pub published_clock: f64,
    pub model: Arc<ModelState>,
}

/// Thread-safe snapshot store: one atomic "current" pointer plus the
/// version-ordered history.
pub struct SnapshotRegistry {
    current: RwLock<Option<Arc<Snapshot>>>,
    history: Mutex<Vec<Arc<Snapshot>>>,
    history_cap: usize,
    next_version: AtomicU64,
}

impl fmt::Debug for SnapshotRegistry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SnapshotRegistry")
            .field("latest_version", &self.latest_version())
            .field("history_len", &self.history.lock().unwrap().len())
            .finish()
    }
}

impl Default for SnapshotRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl SnapshotRegistry {
    /// Registry with unbounded history (replay-capable).
    pub fn new() -> SnapshotRegistry {
        Self::with_history_cap(usize::MAX)
    }

    /// Registry that retains only the `cap` most recent snapshots (long
    /// production runs; `snapshot_at_clock` then only sees that window).
    pub fn with_history_cap(cap: usize) -> SnapshotRegistry {
        SnapshotRegistry {
            current: RwLock::new(None),
            history: Mutex::new(Vec::new()),
            history_cap: cap.max(1),
            next_version: AtomicU64::new(1),
        }
    }

    /// Publish a model: assign the next version, record it in the history,
    /// and atomically swap the current pointer (in that order — readers
    /// only learn of a snapshot once it is fully fetchable). Returns the
    /// version. The intended topology is a single publishing trainer; with
    /// racing publishers the last current-pointer store wins.
    pub fn publish(&self, model: ModelState, mega_batch: Option<usize>, clock: f64) -> u64 {
        let version = self.next_version.fetch_add(1, Ordering::Relaxed);
        let snap = Arc::new(Snapshot {
            version,
            mega_batch,
            published_clock: clock,
            model: Arc::new(model),
        });
        {
            let mut h = self.history.lock().unwrap();
            h.push(snap.clone());
            if h.len() > self.history_cap {
                let drop_n = h.len() - self.history_cap;
                h.drain(..drop_n);
            }
        }
        *self.current.write().unwrap() = Some(snap);
        version
    }

    /// Seed the registry from a saved checkpoint (version with no
    /// mega-batch, clock −1 so it orders before any live publish).
    pub fn load_checkpoint(&self, path: &Path) -> Result<u64> {
        let model = crate::model::checkpoint::load(path)?;
        Ok(self.publish_loaded(model))
    }

    /// Publish an already-loaded artifact model (checkpoint semantics).
    pub fn publish_loaded(&self, model: ModelState) -> u64 {
        self.publish(model, None, -1.0)
    }

    /// The currently-served snapshot (cheap: one `Arc` clone under a read
    /// lock).
    pub fn current(&self) -> Option<Arc<Snapshot>> {
        self.current.read().unwrap().clone()
    }

    /// The snapshot that was live at training-clock `t`: the newest with
    /// `published_clock <= t`, falling back to the oldest retained snapshot
    /// when `t` precedes every publish (serving warm-starts on whatever
    /// model exists). None only when nothing was ever published.
    pub fn snapshot_at_clock(&self, t: f64) -> Option<Arc<Snapshot>> {
        let h = self.history.lock().unwrap();
        h.iter().rev().find(|s| s.published_clock <= t).or_else(|| h.first()).cloned()
    }

    /// Version-ordered publish history (clones of the `Arc`s).
    pub fn history(&self) -> Vec<Arc<Snapshot>> {
        self.history.lock().unwrap().clone()
    }

    /// Version of the currently-served snapshot (0 before the first
    /// publish). Derived from `current`, not the version counter, so it
    /// never names a version a concurrent reader cannot yet fetch —
    /// `publish` bumps the counter before the snapshot becomes visible.
    pub fn latest_version(&self) -> u64 {
        self.current.read().unwrap().as_ref().map(|s| s.version).unwrap_or(0)
    }

    /// True until the first publish is fully visible. `!is_empty()`
    /// guarantees `current()` is `Some` and the history is non-empty (the
    /// current pointer is stored last in `publish`).
    pub fn is_empty(&self) -> bool {
        self.current.read().unwrap().is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelDims;

    fn dims() -> ModelDims {
        ModelDims { features: 32, hidden: 4, classes: 8, max_nnz: 4, max_labels: 2 }
    }

    /// A model whose every parameter equals `v` — torn reads would show as
    /// mixed values.
    fn constant_model(v: f32) -> ModelState {
        let mut m = ModelState::zeros(&dims());
        for seg in m.segments_mut() {
            seg.fill(v);
        }
        m
    }

    fn uniform_value(m: &ModelState) -> Option<f32> {
        let first = m.w1[0];
        m.segments()
            .iter()
            .all(|s| s.iter().all(|&x| x == first))
            .then_some(first)
    }

    #[test]
    fn publish_bumps_versions_and_swaps_current() {
        let reg = SnapshotRegistry::new();
        assert!(reg.is_empty());
        assert!(reg.current().is_none());
        let v1 = reg.publish(constant_model(1.0), Some(0), 0.5);
        let v2 = reg.publish(constant_model(2.0), Some(1), 1.5);
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(reg.latest_version(), 2);
        let cur = reg.current().unwrap();
        assert_eq!(cur.version, 2);
        assert_eq!(uniform_value(&cur.model), Some(2.0));
        assert_eq!(reg.history().len(), 2);
    }

    #[test]
    fn snapshot_at_clock_picks_the_live_version() {
        let reg = SnapshotRegistry::new();
        reg.publish(constant_model(1.0), Some(0), 1.0);
        reg.publish(constant_model(2.0), Some(1), 2.0);
        reg.publish(constant_model(3.0), Some(2), 3.0);
        assert_eq!(reg.snapshot_at_clock(2.5).unwrap().version, 2);
        assert_eq!(reg.snapshot_at_clock(3.0).unwrap().version, 3);
        // Before the first publish: warm-start on the oldest snapshot.
        assert_eq!(reg.snapshot_at_clock(0.1).unwrap().version, 1);
    }

    #[test]
    fn checkpoint_round_trips_into_the_registry() {
        let dir = std::env::temp_dir().join("hs-serve-registry");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("served.ckpt");
        let m = ModelState::init(&dims(), 5);
        crate::model::checkpoint::save(&m, &path).unwrap();

        let reg = SnapshotRegistry::new();
        let v = reg.load_checkpoint(&path).unwrap();
        assert_eq!(v, 1);
        let snap = reg.current().unwrap();
        assert_eq!(snap.mega_batch, None);
        assert!(snap.published_clock < 0.0);
        assert_eq!(*snap.model, m);
        // A checkpoint snapshot serves at any clock.
        assert_eq!(reg.snapshot_at_clock(0.0).unwrap().version, 1);
        assert!(reg.load_checkpoint(&dir.join("missing.ckpt")).is_err());
    }

    #[test]
    fn history_cap_keeps_only_the_tail() {
        let reg = SnapshotRegistry::with_history_cap(2);
        for i in 0..5 {
            reg.publish(constant_model(i as f32), Some(i), i as f64);
        }
        let h = reg.history();
        assert_eq!(h.len(), 2);
        assert_eq!(h[0].version, 4);
        assert_eq!(h[1].version, 5);
        assert_eq!(reg.current().unwrap().version, 5);
    }

    /// Concurrent publishes against concurrent reads: every read observes a
    /// fully-published model (all parameters from the same version) and
    /// versions move monotonically.
    #[test]
    fn hot_swap_is_atomic_under_concurrent_publishes() {
        let reg = Arc::new(SnapshotRegistry::with_history_cap(4));
        reg.publish(constant_model(0.0), Some(0), 0.0);
        let writer = {
            let reg = reg.clone();
            std::thread::spawn(move || {
                for i in 1..200u32 {
                    reg.publish(constant_model(i as f32), Some(i as usize), i as f64);
                }
            })
        };
        let mut last_version = 0;
        for _ in 0..2000 {
            let snap = reg.current().unwrap();
            let v = uniform_value(&snap.model)
                .expect("served model must never mix parameter versions");
            assert_eq!(v as u64 + 1, snap.version, "model content matches its version");
            assert!(snap.version >= last_version, "versions move forward");
            last_version = snap.version;
        }
        writer.join().unwrap();
        assert_eq!(reg.current().unwrap().version, 200);
    }
}

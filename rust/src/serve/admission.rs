//! Deadline-aware micro-batch admission for the serving plane.
//!
//! Sparse requests accumulate into [`PaddedBatch`]es on the *training*
//! batch-size grid (the AOT executables only exist for grid shapes):
//!
//! * a **full** batch forms the moment `serve.max_batch` requests are
//!   pending,
//! * a **partial** batch flushes when the oldest pending request has
//!   waited `serve.max_delay` seconds — latency SLOs beat batching
//!   efficiency — padded to the smallest grid bucket that fits.
//!
//! The hot path reuses the data plane's machinery: samples pad through
//! [`pad_sample_into`] (same rules as training) and batch buffers recycle
//! through a [`BufferPool`], so steady-state admission performs no
//! per-request buffer allocation.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::config::{Config, ModelDims};
use crate::data::batcher::{pad_sample_into, PaddedBatch};
use crate::data::pipeline::{BufferPool, PoolStats, ShardedDataset};
use crate::obs::{CounterHandle, ObsHandle};

/// A request waiting for batch formation.
#[derive(Clone, Copy, Debug)]
struct PendingRequest {
    id: u64,
    sample_id: u32,
    arrival: f64,
}

/// One formed micro-batch, ready for routing. `request_ids` / `arrivals`
/// are parallel to the batch's valid rows.
#[derive(Debug)]
pub struct AdmittedBatch {
    pub batch: PaddedBatch,
    pub request_ids: Vec<u64>,
    pub arrivals: Vec<f64>,
    pub formed_at: f64,
}

/// The admission queue: requests in, grid-shaped micro-batches out.
pub struct Admission {
    data: Arc<ShardedDataset>,
    k: usize,
    l: usize,
    /// Ascending grid buckets up to (and including) `max_batch`.
    grid: Vec<usize>,
    max_batch: usize,
    max_delay: f64,
    pool: BufferPool,
    pending: VecDeque<PendingRequest>,
    /// Cumulative counters (telemetry) — registry-backed under `serve.*`
    /// dotted names so the obs plane exports the same atomics.
    pub admitted: CounterHandle,
    pub formed_batches: CounterHandle,
    pub deadline_flushes: CounterHandle,
    pub truncated_features: CounterHandle,
}

impl Admission {
    pub fn new(data: Arc<ShardedDataset>, dims: &ModelDims, cfg: &Config) -> Admission {
        Admission::new_obs(data, dims, cfg, &ObsHandle::disabled())
    }

    /// [`Admission::new`] with the counters registered in `obs`'s registry
    /// (the replay loop passes its handle so admission telemetry lands in
    /// the shared metrics snapshot).
    pub fn new_obs(
        data: Arc<ShardedDataset>,
        dims: &ModelDims,
        cfg: &Config,
        obs: &ObsHandle,
    ) -> Admission {
        let max_batch = cfg.serve_max_batch();
        let grid: Vec<usize> =
            cfg.bucket_grid().into_iter().filter(|&b| b <= max_batch).collect();
        assert!(
            grid.last() == Some(&max_batch),
            "serve.max_batch must lie on the bucket grid (validated in config)"
        );
        Admission {
            data,
            k: dims.max_nnz,
            l: dims.max_labels,
            grid,
            max_batch,
            max_delay: cfg.serve.max_delay,
            pool: BufferPool::new(8),
            pending: VecDeque::new(),
            admitted: obs.counter("serve.admitted"),
            formed_batches: obs.counter("serve.formed_batches"),
            deadline_flushes: obs.counter("serve.deadline_flushes"),
            truncated_features: obs.counter("serve.truncated_features"),
        }
    }

    /// Enqueue one request.
    pub fn push(&mut self, id: u64, sample_id: u32, arrival: f64) {
        debug_assert!((sample_id as usize) < self.data.len());
        self.admitted.inc();
        self.pending.push_back(PendingRequest { id, sample_id, arrival });
    }

    pub fn queue_depth(&self) -> usize {
        self.pending.len()
    }

    /// When the queue must flush even if not full: the oldest pending
    /// request's arrival plus the formation deadline. None when idle.
    pub fn deadline(&self) -> Option<f64> {
        self.pending.front().map(|r| r.arrival + self.max_delay)
    }

    /// Form a full `max_batch` batch if enough requests are pending.
    pub fn pop_full(&mut self, now: f64) -> Option<AdmittedBatch> {
        (self.pending.len() >= self.max_batch).then(|| self.form(self.max_batch, now))
    }

    /// Flush everything pending (the deadline hit, or the trace ended):
    /// the batch pads up to the smallest grid bucket that fits.
    pub fn flush(&mut self, now: f64) -> Option<AdmittedBatch> {
        if self.pending.is_empty() {
            return None;
        }
        self.deadline_flushes.inc();
        let count = self.pending.len().min(self.max_batch);
        Some(self.form(count, now))
    }

    fn form(&mut self, count: usize, now: f64) -> AdmittedBatch {
        // Smallest grid bucket covering `count` (grid ends at max_batch).
        let bucket =
            self.grid.iter().copied().find(|&b| b >= count).unwrap_or(self.max_batch);
        let mut batch = self.pool.get(bucket, self.k, self.l);
        let mut request_ids = Vec::with_capacity(count);
        let mut arrivals = Vec::with_capacity(count);
        let mut truncated = 0usize;
        for row in 0..count {
            let req = self.pending.pop_front().expect("count <= pending.len()");
            let s = self.data.sample(req.sample_id as usize);
            truncated += pad_sample_into(&mut batch, row, req.sample_id, &s, self.k, self.l);
            request_ids.push(req.id);
            arrivals.push(req.arrival);
        }
        batch.valid = count;
        self.truncated_features.add(truncated as u64);
        self.formed_batches.inc();
        AdmittedBatch { batch, request_ids, arrivals, formed_at: now }
    }

    /// Return a served batch's buffers to the pool.
    pub fn recycle(&self, batch: PaddedBatch) {
        self.pool.put(batch);
    }

    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::data::synthetic::Generator;

    fn setup() -> (Config, Arc<ShardedDataset>) {
        let mut cfg = Config::default();
        cfg.model = ModelDims { features: 256, hidden: 8, classes: 32, max_nnz: 16, max_labels: 4 };
        cfg.sgd.b_min = 8;
        cfg.sgd.b_max = 32;
        cfg.sgd.beta = 8;
        cfg.sgd.initial_batch = 32;
        cfg.validate().unwrap();
        let data_cfg = DataConfig { train_samples: 300, avg_nnz: 6.0, ..Default::default() };
        let ds = Generator::new(&cfg.model, &data_cfg).generate(300, 1);
        (cfg, Arc::new(ShardedDataset::from_dataset(&ds, 128)))
    }

    #[test]
    fn full_batches_form_at_max_batch() {
        let (cfg, data) = setup();
        let mut adm = Admission::new(data.clone(), &cfg.model, &cfg);
        for i in 0..31 {
            adm.push(i, i as u32, i as f64 * 1e-4);
            assert!(adm.pop_full(0.01).is_none(), "not full at {}", i + 1);
        }
        adm.push(31, 31, 31e-4);
        let b = adm.pop_full(0.01).unwrap();
        assert_eq!(b.batch.bucket, 32);
        assert_eq!(b.batch.valid, 32);
        assert_eq!(b.request_ids, (0..32).collect::<Vec<u64>>());
        assert_eq!(b.batch.sample_ids.len(), 32);
        assert_eq!(b.formed_at, 0.01);
        assert_eq!(adm.queue_depth(), 0);
        assert_eq!(adm.formed_batches.get(), 1);
        assert_eq!(adm.deadline_flushes.get(), 0);
    }

    #[test]
    fn deadline_flush_pads_to_the_smallest_fitting_bucket() {
        let (cfg, data) = setup(); // grid {8, 16, 24, 32}
        let mut adm = Admission::new(data.clone(), &cfg.model, &cfg);
        for i in 0..11 {
            adm.push(i, i as u32, 0.001);
        }
        assert_eq!(adm.deadline(), Some(0.001 + cfg.serve.max_delay));
        let b = adm.flush(0.004).unwrap();
        assert_eq!(b.batch.valid, 11);
        assert_eq!(b.batch.bucket, 16, "11 requests pad to the 16 bucket");
        assert_eq!(adm.deadline(), None, "queue drained");
        assert_eq!(adm.deadline_flushes.get(), 1);
        assert!(adm.flush(0.01).is_none(), "empty queue has nothing to flush");
        // A 3-request flush lands on the smallest bucket.
        for i in 0..3 {
            adm.push(100 + i, i as u32, 0.02);
        }
        let b = adm.flush(0.03).unwrap();
        assert_eq!((b.batch.valid, b.batch.bucket), (3, 8));
    }

    #[test]
    fn batch_buffers_recycle_through_the_pool() {
        let (cfg, data) = setup();
        let mut adm = Admission::new(data.clone(), &cfg.model, &cfg);
        for round in 0..3u64 {
            for i in 0..32 {
                adm.push(round * 32 + i, i as u32, round as f64);
            }
            let b = adm.pop_full(round as f64).unwrap();
            adm.recycle(b.batch);
        }
        let s = adm.pool_stats();
        assert_eq!(s.misses, 1, "only the first batch allocates");
        assert_eq!(s.hits, 2, "later batches reuse the buffers");
    }

    #[test]
    fn truncation_is_counted_not_silent() {
        let (mut cfg, _) = setup();
        // Regenerate with wide samples, then serve under a tight max_nnz.
        let gen_dims =
            ModelDims { features: 256, hidden: 8, classes: 32, max_nnz: 16, max_labels: 4 };
        let data_cfg = DataConfig { train_samples: 100, avg_nnz: 10.0, ..Default::default() };
        let ds = Generator::new(&gen_dims, &data_cfg).generate(100, 1);
        let data = Arc::new(ShardedDataset::from_dataset(&ds, 64));
        cfg.model.max_nnz = 4;
        let mut adm = Admission::new(data.clone(), &cfg.model, &cfg);
        for i in 0..32u64 {
            adm.push(i, i as u32, 0.0);
        }
        let b = adm.pop_full(0.0).unwrap();
        let expected: u64 = b
            .batch
            .sample_ids
            .iter()
            .map(|&id| data.nnz(id as usize).saturating_sub(4) as u64)
            .sum();
        assert!(expected > 0, "corpus should overflow max_nnz=4");
        assert_eq!(adm.truncated_features.get(), expected);
    }
}

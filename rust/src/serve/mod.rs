//! The serving plane: online inference over trained snapshots — the
//! train→serve loop the ROADMAP north star asks for ("serves heavy traffic
//! from millions of users") built from the same primitives as training.
//!
//! * [`registry`] — [`SnapshotRegistry`]: versioned immutable
//!   `Arc<ModelState>` snapshots with atomic hot-swap; fed by the trainer's
//!   publish hook at mega-batch boundaries and by `model::checkpoint`
//!   files.
//! * [`admission`] — [`Admission`]: deadline-aware micro-batching of
//!   sparse requests onto the training bucket grid, reusing
//!   `pad_sample_into` + `BufferPool` so steady-state admission performs
//!   no per-request buffer allocation.
//! * [`router`] — [`Router`]: speed-aware routing over the device roster
//!   (earliest-virtual-free-time, the same rule as training's dynamic
//!   dispatch); pool churn shrinks/grows capacity live while in-flight
//!   batches drain.
//! * [`traffic`] — open-loop workload generation (Poisson / bursty
//!   arrivals, nnz-biased draws from the shard manifests).
//! * [`latency`] — windowed p50/p95/p99, throughput, queue depth, batch
//!   fill, staleness, and served-accuracy telemetry.
//!
//! [`replay`] ties them together as a deterministic discrete-event loop on
//! the same virtual clock training uses, which is what makes serving runs
//! bit-reproducible (`integration_serve.rs` pins this) and lets
//! train-while-serve interleave a recorded publish timeline with a traffic
//! trace without nondeterministic threads.

pub mod admission;
pub mod latency;
pub mod registry;
pub mod router;
pub mod traffic;

pub use admission::{AdmittedBatch, Admission};
pub use latency::{BatchRecord, RequestRecord, ServeLog, ServeWindow};
pub use registry::{Snapshot, SnapshotRegistry};
pub use router::{Routed, Router};
pub use traffic::Arrival;

use std::sync::Arc;

use crate::config::{Config, ServePattern};
use crate::coordinator::backend::StepBackend;
use crate::coordinator::DevicePool;
use crate::data::pipeline::ShardedDataset;
use crate::metrics::RunLog;
use crate::runtime::CostModel;
use crate::Result;

/// How one replay run is driven.
#[derive(Clone, Debug)]
pub struct ReplayOptions<'a> {
    pub pattern: ServePattern,
    /// Trace length in virtual seconds. For train-while-serve pass the
    /// training run's final clock so the serving timeline spans training.
    pub duration: f64,
    /// Follow the registry's publish timeline (`snapshot_at_clock`) instead
    /// of always serving the latest snapshot — train-while-serve replay.
    pub follow_clock: bool,
    /// Training run to measure staleness / accuracy tracking against:
    /// timeline replays (`follow_clock`) measure staleness at formation
    /// time, steady-state replays against the end of the run.
    pub train_log: Option<&'a RunLog>,
    pub name: String,
    /// Observability handle: serve spans land on `serve-gpu*` lanes of
    /// this handle's sink and admission/router counters register in its
    /// registry. `crate::obs::ambient()` picks up whatever the CLI
    /// installed (a disabled handle when `[obs]` is off).
    pub obs: crate::obs::ObsHandle,
}

/// Replay a synthetic trace against the registry on a virtual clock:
/// generate arrivals, micro-batch them under the admission deadline, route
/// speed-aware over the (churning) serving pool, evaluate against the live
/// snapshot, and fold telemetry into windows.
///
/// Deterministic for a fixed (config, corpus, registry content): same seed
/// → bit-identical `ServeLog`.
pub fn replay(
    cfg: &Config,
    data: Arc<ShardedDataset>,
    registry: &SnapshotRegistry,
    eval_backend: &dyn StepBackend,
    opts: &ReplayOptions<'_>,
) -> Result<ServeLog> {
    anyhow::ensure!(!registry.is_empty(), "nothing to serve: the snapshot registry is empty");
    let arrivals =
        traffic::generate(opts.pattern, &cfg.serve, &data, opts.duration, cfg.serve.seed);

    let obs = opts.obs.clone();
    let latency_hist = obs.histogram("serve.latency_s");
    let mut admission = Admission::new_obs(data.clone(), &cfg.model, cfg, &obs);
    let mut pool = DevicePool::with_trace(cfg, &cfg.serve.events)?;
    let mut router =
        Router::new_obs(DevicePool::roster(cfg), pool.active_ids(), CostModel::default(), &obs);
    // Sparsity lever: with `[slide] serve_slo_ms > 0` the router watches the
    // windowed p95 and flips replicas to approximate LSH top-k inference at
    // `serve_ratio` under SLO pressure. Disarmed (the default) this whole
    // block is inert and the replay is bit-identical to the exact path.
    router.configure_slo(&cfg.slide);
    let mut stepper = crate::slide::SparseStepper::new(&cfg.slide, 0x5E4E);
    stepper.set_ratio(cfg.slide.serve_ratio);
    let mut scratch = crate::model::reference::StepScratch::new();

    let window = cfg.serve.window;
    let mut requests: Vec<RequestRecord> = Vec::with_capacity(arrivals.len());
    let mut batches: Vec<BatchRecord> = Vec::new();
    let mut depth_samples: Vec<(f64, usize)> = Vec::new();
    let mut pool_events: Vec<crate::metrics::PoolEventRow> = Vec::new();

    // Scripted churn lands at telemetry-window boundaries (the serving
    // analog of training's mega-batch barrier); `next_window` is the next
    // boundary not yet applied.
    let mut next_window = 0usize;
    let mut churn_until = |t: f64,
                           pool: &mut DevicePool,
                           router: &mut Router,
                           pool_events: &mut Vec<crate::metrics::PoolEventRow>| {
        while (next_window as f64) * window <= t {
            let events = pool.begin_mega_batch(next_window);
            if !events.is_empty() {
                router.set_active(&pool.active_ids());
            }
            for ev in events {
                obs.instant(
                    crate::obs::Subsystem::Serve,
                    "serve.churn",
                    crate::obs::chrome::SERVE_TID_BASE + ev.device as u32,
                    (next_window as f64) * window,
                    vec![
                        ("device", ev.device.into()),
                        ("action", ev.action.name().into()),
                        ("reason", ev.reason.as_str().into()),
                    ],
                );
                pool_events.push(crate::metrics::PoolEventRow {
                    mega_batch: ev.mega_batch,
                    device: ev.device,
                    action: ev.action.name().to_string(),
                    reason: ev.reason.clone(),
                });
            }
            next_window += 1;
        }
    };

    let dispatch = |ab: AdmittedBatch,
                        admission: &Admission,
                        router: &mut Router,
                        stepper: &mut crate::slide::SparseStepper,
                        scratch: &mut crate::model::reference::StepScratch,
                        requests: &mut Vec<RequestRecord>,
                        batches: &mut Vec<BatchRecord>|
     -> Result<()> {
        let t = ab.formed_at;
        let snap = if opts.follow_clock {
            registry.snapshot_at_clock(t)
        } else {
            registry.current()
        }
        .expect("registry checked non-empty");
        let routed = router.route(t, &ab.batch);
        let preds = if router.approx_mode() {
            stepper.eval(&snap.model, &ab.batch, scratch)
        } else {
            eval_backend.eval_scratch(&snap.model, &ab.batch, scratch)?
        };
        // Staleness in mega-batches: how far training had moved past the
        // served snapshot. Timeline replays measure against the training
        // clock at formation time; steady-state (post-training) serving
        // measures against the end of the run.
        let staleness = match (opts.train_log, snap.mega_batch) {
            (Some(log), Some(p)) => {
                let completed = if opts.follow_clock {
                    log.mega_batches_completed_at(t)
                } else {
                    log.rows.len()
                };
                Some(completed.saturating_sub(p + 1))
            }
            _ => None,
        };
        for (row, (&rid, &arrival)) in ab.request_ids.iter().zip(&ab.arrivals).enumerate() {
            let sample_id = ab.batch.sample_ids[row] as usize;
            let hit = data.sample(sample_id).labels.contains(&(preds[row].max(0) as u32));
            router.observe_latency_at(routed.completion, routed.completion - arrival);
            latency_hist.observe(routed.completion - arrival);
            requests.push(RequestRecord {
                id: rid,
                arrival,
                completion: routed.completion,
                hit,
            });
        }
        // One span per served micro-batch on the device's serve lane:
        // admit (formed_at) → route (start) → eval → respond (completion).
        obs.span(
            crate::obs::Subsystem::Serve,
            "serve.batch",
            crate::obs::chrome::SERVE_TID_BASE + routed.device as u32,
            routed.start,
            routed.completion - routed.start,
            vec![
                ("valid", ab.batch.valid.into()),
                ("bucket", ab.batch.bucket.into()),
                ("version", snap.version.into()),
                ("queued_s", (routed.start - t).into()),
                ("approx", router.approx_mode().into()),
            ],
        );
        batches.push(BatchRecord {
            formed_at: t,
            start: routed.start,
            completion: routed.completion,
            device: routed.device,
            bucket: ab.batch.bucket,
            valid: ab.batch.valid,
            version: snap.version,
            staleness,
        });
        admission.recycle(ab.batch);
        Ok(())
    };

    // Discrete-event loop: the next event is either the next arrival or the
    // oldest pending request's formation deadline, whichever is earlier
    // (ties go to the arrival so the deadline flush sees the full queue).
    let mut i = 0usize;
    let mut next_id = 0u64;
    while i < arrivals.len() || admission.queue_depth() > 0 {
        let t_arr = arrivals.get(i).map(|a| a.at).unwrap_or(f64::INFINITY);
        let t_dead = admission.deadline().unwrap_or(f64::INFINITY);
        if t_arr <= t_dead {
            churn_until(t_arr, &mut pool, &mut router, &mut pool_events);
            admission.push(next_id, arrivals[i].sample_id, t_arr);
            next_id += 1;
            i += 1;
            depth_samples.push((t_arr, admission.queue_depth()));
            while let Some(ab) = admission.pop_full(t_arr) {
                dispatch(
                    ab,
                    &admission,
                    &mut router,
                    &mut stepper,
                    &mut scratch,
                    &mut requests,
                    &mut batches,
                )?;
            }
        } else {
            churn_until(t_dead, &mut pool, &mut router, &mut pool_events);
            if let Some(ab) = admission.flush(t_dead) {
                dispatch(
                    ab,
                    &admission,
                    &mut router,
                    &mut stepper,
                    &mut scratch,
                    &mut requests,
                    &mut batches,
                )?;
            }
        }
    }

    Ok(ServeLog::summarize(
        opts.name.clone(),
        opts.duration,
        window,
        requests,
        batches,
        &depth_samples,
        pool_events,
        opts.train_log,
    ))
}

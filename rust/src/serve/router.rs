//! Speed-aware micro-batch routing over the device roster.
//!
//! Each active device holds an inference replica (a clone of the current
//! snapshot `Arc` — pointer, not parameters), and admitted batches route
//! with the *same rule training uses for dynamic dispatch*: the batch goes
//! to the active device with the earliest virtual free time, ties broken
//! toward the lower id. Faster devices therefore drain more batches per
//! second, exactly proportional to their relative throughput — no static
//! partitioning, no weights to tune.
//!
//! Pool churn (`[serve] events` through [`DevicePool::begin_mega_batch`])
//! shrinks or grows serving capacity live: [`Router::set_active`] only
//! affects *future* routing decisions, so batches already dispatched to a
//! removed device drain to completion — every admitted request is answered
//! exactly once across churn.
//!
//! With the calibration plane on ([`Router::set_cost_view`]), routing
//! upgrades to earliest *predicted completion* on the shared
//! [`CostsView`] — the same estimates training dispatch uses — so a
//! device the estimators have watched throttle stops winning batches it
//! will finish late, before its own queue has to reveal the slowdown.
//!
//! [`DevicePool::begin_mega_batch`]: crate::coordinator::DevicePool::begin_mega_batch

use std::sync::Arc;

use crate::coordinator::dispatch::{next_completion_device, next_free_device};
use crate::data::PaddedBatch;
use crate::obs::{CounterHandle, ObsHandle};
use crate::runtime::{CostModel, SimDevice};
use crate::tuning::CostsView;

/// Outcome of routing one micro-batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Routed {
    /// Device (global roster id) that serves the batch.
    pub device: usize,
    /// Virtual time service starts (>= formation time; queueing shows up
    /// as `start − formed_at`).
    pub start: f64,
    /// Virtual completion time.
    pub completion: f64,
}

/// Earliest-free routing over the roster's heterogeneity model.
pub struct Router {
    devices: Vec<SimDevice>,
    free_time: Vec<f64>,
    active: Vec<usize>,
    /// Roster-indexed membership mask mirroring `active` (the dispatch-rule
    /// eligibility predicate).
    active_mask: Vec<bool>,
    cost: CostModel,
    /// Calibrated costs view (None = historical earliest-free routing).
    view: Option<Arc<CostsView>>,
    /// Reusable per-route prediction buffer (hot path: no allocation per
    /// micro-batch).
    pred_secs: Vec<f64>,
    routed: Vec<u64>,
    /// Latency SLO in seconds (0 = the sparsity lever is off and routing
    /// is bit-identical to the historical exact-only path).
    slo: f64,
    /// Active-class ratio replicas run while in approximate mode.
    serve_ratio: f64,
    /// Whether replicas currently serve approximate (LSH top-k) inference.
    approx: bool,
    /// Sliding window of observed request latencies (ring buffer).
    lat_window: Vec<f64>,
    lat_pos: usize,
    /// Exact↔approximate transitions, registry-backed as
    /// `serve.mode_switches`.
    mode_switches: CounterHandle,
    /// Obs plane for `serve.mode` decision instants (mode flips carry
    /// the p95/SLO inputs that drove them).
    obs: ObsHandle,
}

/// Latency samples the router keeps for its windowed p95.
const LAT_WINDOW_CAP: usize = 64;
/// Don't flip modes off fewer samples than this — one stray tail latency
/// at cold start shouldn't degrade accuracy fleet-wide.
const LAT_MIN_SAMPLES: usize = 16;

impl Router {
    /// `devices` is the full roster ([`DevicePool::roster`]); `active` the
    /// initially-active subset.
    pub fn new(devices: Vec<SimDevice>, active: Vec<usize>, cost: CostModel) -> Router {
        Router::new_obs(devices, active, cost, &ObsHandle::disabled())
    }

    /// [`Router::new`] with the mode-switch counter registered in `obs`'s
    /// registry (the replay loop passes its handle).
    pub fn new_obs(
        devices: Vec<SimDevice>,
        active: Vec<usize>,
        cost: CostModel,
        obs: &ObsHandle,
    ) -> Router {
        assert!(!devices.is_empty());
        let n = devices.len();
        let mut r = Router {
            devices,
            free_time: vec![0.0; n],
            active: Vec::new(),
            active_mask: vec![false; n],
            cost,
            view: None,
            pred_secs: Vec::with_capacity(n),
            routed: vec![0; n],
            slo: 0.0,
            serve_ratio: 1.0,
            approx: false,
            lat_window: Vec::with_capacity(LAT_WINDOW_CAP),
            lat_pos: 0,
            mode_switches: obs.counter("serve.mode_switches"),
            obs: obs.clone(),
        };
        r.set_active(&active);
        r
    }

    /// Apply a pool-membership (or fleet-lease) change. In-flight work on
    /// departed devices drains (their `free_time` stays); only future
    /// routing changes. Under the fleet scheduler the serve lane calls this
    /// with its *leased* device set, so serving capacity is whatever the
    /// arbiter granted — not the raw roster.
    pub fn set_active(&mut self, ids: &[usize]) {
        assert!(!ids.is_empty(), "serving needs at least one active device");
        assert!(ids.iter().all(|&d| d < self.devices.len()), "active id outside roster");
        self.active = ids.to_vec();
        self.active_mask.fill(false);
        for &d in ids {
            self.active_mask[d] = true;
        }
    }

    pub fn active(&self) -> &[usize] {
        &self.active
    }

    /// Route on this calibrated-costs view (`[calibration]` plane): the
    /// next batch goes to the active device with the earliest *predicted
    /// completion* under the view's estimated speeds. `None` restores the
    /// historical earliest-free rule bit-for-bit. The fleet co-scheduler
    /// refreshes this every decision window.
    pub fn set_cost_view(&mut self, view: Option<Arc<CostsView>>) {
        if let Some(v) = &view {
            assert_eq!(v.roster_len(), self.devices.len(), "view must cover the roster");
        }
        self.view = view;
    }

    /// Route one batch at time `now`: earliest-free active device wins
    /// (training's dynamic-dispatch rule, shared via
    /// `coordinator::dispatch`), then its virtual clock advances by the
    /// heterogeneity-modeled inference duration.
    pub fn route(&mut self, now: f64, batch: &PaddedBatch) -> Routed {
        let ratio = if self.approx { self.serve_ratio } else { 1.0 };
        let device = match &self.view {
            Some(view) => {
                let nominal = self.cost.infer_time_parts_at(batch.bucket, batch.nnz, ratio);
                self.pred_secs.clear();
                self.pred_secs.extend((0..self.devices.len()).map(|d| view.speed(d) * nominal));
                next_completion_device(&self.free_time, now, &self.pred_secs, |d| {
                    self.active_mask[d]
                })
            }
            None => next_free_device(&self.free_time, now, |d| self.active_mask[d]),
        }
        .expect("router has an active device");
        let start = self.free_time[device].max(now);
        let completion = start + self.devices[device].infer_duration_at(&self.cost, batch, ratio);
        self.free_time[device] = completion;
        self.routed[device] += 1;
        Routed { device, start, completion }
    }

    /// Arm the sparsity lever (`[slide] serve_slo_ms` / `serve_ratio`):
    /// when the windowed p95 of observed latencies nears `slo` the router
    /// flips replicas to approximate LSH top-k inference at `serve_ratio`,
    /// and flips back to exact once load subsides. `serve_slo_ms = 0`
    /// (the default) leaves every route bit-identical to the exact path.
    pub fn configure_slo(&mut self, sec: &crate::config::SlideConfig) {
        self.slo = sec.serve_slo_ms / 1_000.0;
        self.serve_ratio = sec.serve_ratio;
    }

    /// Feed one completed request's latency (seconds, virtual clock) into
    /// the SLO window. Hysteresis keeps the mode from flapping: engage
    /// approximate at p95 ≥ 0.9·SLO, return to exact at p95 ≤ 0.6·SLO.
    /// Callers with a clock should prefer
    /// [`Router::observe_latency_at`], which timestamps the mode-flip
    /// decision record.
    pub fn observe_latency(&mut self, latency: f64) {
        self.observe_latency_at(f64::NAN, latency);
    }

    /// [`Router::observe_latency`] at virtual time `now`: a mode flip
    /// emits a `serve.mode` decision instant carrying the windowed p95
    /// and the SLO thresholds that drove it (skipped when `now` is NaN —
    /// clock-less callers keep the tally but not the audit row).
    pub fn observe_latency_at(&mut self, now: f64, latency: f64) {
        if self.slo <= 0.0 {
            return;
        }
        if self.lat_window.len() < LAT_WINDOW_CAP {
            self.lat_window.push(latency);
        } else {
            self.lat_window[self.lat_pos] = latency;
            self.lat_pos = (self.lat_pos + 1) % LAT_WINDOW_CAP;
        }
        if self.lat_window.len() < LAT_MIN_SAMPLES {
            return;
        }
        let p95 = self.windowed_p95();
        let flipped_to = if !self.approx && p95 >= 0.9 * self.slo {
            self.approx = true;
            self.mode_switches.inc();
            Some("approx")
        } else if self.approx && p95 <= 0.6 * self.slo {
            self.approx = false;
            self.mode_switches.inc();
            Some("exact")
        } else {
            None
        };
        if let (Some(mode), true) = (flipped_to, now.is_finite()) {
            self.obs.instant(
                crate::obs::Subsystem::Serve,
                "serve.mode",
                0,
                now,
                vec![
                    ("action", mode.into()),
                    ("p95_s", p95.into()),
                    ("slo_s", self.slo.into()),
                    ("ratio", self.serve_ratio.into()),
                ],
            );
        }
    }

    /// Windowed p95 of observed latencies (0 before any observation).
    pub fn windowed_p95(&self) -> f64 {
        if self.lat_window.is_empty() {
            return 0.0;
        }
        let mut sorted = self.lat_window.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let idx = ((sorted.len() as f64 * 0.95).ceil() as usize).max(1) - 1;
        sorted[idx.min(sorted.len() - 1)]
    }

    /// Whether replicas are currently serving approximate inference.
    pub fn approx_mode(&self) -> bool {
        self.approx
    }

    /// How many exact↔approximate transitions have happened.
    pub fn mode_switches(&self) -> u64 {
        self.mode_switches.get()
    }

    /// Batches routed per roster device so far.
    pub fn routed(&self) -> &[u64] {
        &self.routed
    }

    /// Apply a scripted drift multiplier to one serving device — the
    /// serve-side mirror of
    /// [`ExecutionEngine::set_drift`](crate::coordinator::ExecutionEngine::set_drift).
    /// Drift traces are *window-indexed per plane*: the fleet
    /// co-scheduler applies them here at arbiter-tick boundaries, while
    /// each training session applies them at its own mega-batch
    /// boundaries — size `fleet.decision_window` near a mega-batch
    /// duration when a scenario needs the two planes' ramps aligned in
    /// virtual time.
    pub fn set_drift(&mut self, device: usize, multiplier: f64) {
        if let Some(d) = self.devices.get_mut(device) {
            d.set_drift(multiplier);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn batch(bucket: usize, nnz: usize) -> PaddedBatch {
        let mut b = PaddedBatch::with_shape(bucket, 4, 2);
        b.valid = bucket;
        b.nnz = nnz;
        b
    }

    fn router(jitter: f64) -> Router {
        let cfg = DeviceConfig { jitter, ..Default::default() }; // factors 1.0..1.32
        Router::new(SimDevice::fleet(&cfg), vec![0, 1, 2, 3], CostModel::default())
    }

    #[test]
    fn faster_devices_serve_more_batches() {
        let mut r = router(0.0);
        let b = batch(32, 32 * 12);
        let mut last_completion = 0.0f64;
        for _ in 0..400 {
            last_completion = r.route(0.0, &b).completion.max(last_completion);
        }
        let routed = r.routed().to_vec();
        assert_eq!(routed.iter().sum::<u64>(), 400);
        assert!(routed[0] > routed[3], "fastest beats slowest: {routed:?}");
        // Share tracks relative speed (1.32 gap ⇒ roughly 32% more work).
        let ratio = routed[0] as f64 / routed[3] as f64;
        assert!((1.2..1.5).contains(&ratio), "throughput ratio {ratio}");
        assert!(last_completion > 0.0);
    }

    #[test]
    fn idle_routing_starts_at_now_and_queues_stack() {
        let mut r = router(0.0);
        let b = batch(16, 16 * 12);
        let first = r.route(5.0, &b);
        assert_eq!(first.start, 5.0, "idle device starts at the request time");
        // Saturate device 0 (all four then one more).
        for _ in 0..3 {
            r.route(5.0, &b);
        }
        let queued = r.route(5.0, &b);
        assert!(queued.start > 5.0, "fifth batch queues behind the first round");
        assert!(queued.completion > queued.start);
    }

    #[test]
    fn churn_only_affects_future_routing() {
        let mut r = router(0.0);
        let b = batch(32, 32 * 12);
        for _ in 0..8 {
            r.route(0.0, &b);
        }
        let before = r.routed().to_vec();
        r.set_active(&[1, 2]);
        for _ in 0..10 {
            r.route(1.0, &b);
        }
        let after = r.routed().to_vec();
        assert_eq!(after[0], before[0], "removed device gets no new work");
        assert_eq!(after[3], before[3]);
        assert_eq!(after[1] + after[2] - before[1] - before[2], 10);
        // Re-adding resumes routing to the whole fleet.
        r.set_active(&[0, 1, 2, 3]);
        for _ in 0..4 {
            r.route(50.0, &b);
        }
        assert!(r.routed()[0] > before[0]);
    }

    #[test]
    fn cost_view_steers_routing_away_from_a_throttled_device() {
        use crate::tuning::{CalibratedCosts, DeviceEstimate};
        let mut r = router(0.0);
        let b = batch(32, 32 * 12);
        // The view knows device 0 (nominally fastest) throttled to 3x.
        let costs = CalibratedCosts::new(vec![1.0, 1.1, 1.21, 1.32]);
        costs.update_devices(
            &[(
                0,
                DeviceEstimate {
                    speed: 3.0,
                    t_fixed: 300e-6,
                    slope: 3.0,
                    residual_rel: 0.01,
                    observations: 6,
                    drift_events: 1,
                    sparsity_floor: 0.1,
                },
            )],
            0.0,
        );
        r.set_cost_view(Some(costs.current()));
        // Earliest-free would hand device 0 the very first batch (all
        // idle, lowest id). Predicted-completion routing sends the first
        // four batches elsewhere — the view demotes the throttled device
        // before its own queue could reveal the slowdown.
        for _ in 0..4 {
            r.route(0.0, &b);
        }
        let routed = r.routed().to_vec();
        assert_eq!(routed.iter().sum::<u64>(), 4, "every batch still routed exactly once");
        assert_eq!(routed[0], 0, "throttled device never wins early work: {routed:?}");
        // Dropping the view restores the earliest-free rule.
        r.set_cost_view(None);
        let routed_before = r.routed()[0];
        r.route(1e9, &b);
        assert_eq!(r.routed()[0], routed_before + 1, "idle lowest id wins again");
    }

    #[test]
    fn slo_pressure_engages_approx_mode_with_hysteresis() {
        let slide = crate::config::SlideConfig {
            serve_slo_ms: 10.0,
            serve_ratio: 0.25,
            ..Default::default()
        };
        let mut r = router(0.0);
        r.configure_slo(&slide);
        assert!(!r.approx_mode());
        // Healthy latencies: stays exact.
        for _ in 0..32 {
            r.observe_latency(2e-3);
        }
        assert!(!r.approx_mode());
        assert_eq!(r.mode_switches(), 0);
        // Load spike pushes p95 past 0.9·SLO → approximate engages.
        for _ in 0..32 {
            r.observe_latency(9.5e-3);
        }
        assert!(r.approx_mode(), "p95 {} should engage approx", r.windowed_p95());
        assert_eq!(r.mode_switches(), 1);
        // Approximate routes are cheaper than exact ones on the same device.
        let b = batch(32, 32 * 12);
        let approx_cost = {
            let routed = r.route(1e6, &b);
            routed.completion - routed.start
        };
        // Mild recovery (between the two thresholds) must NOT flap back.
        for _ in 0..40 {
            r.observe_latency(7.5e-3);
        }
        assert!(r.approx_mode(), "hysteresis band holds the approximate mode");
        // Full recovery drops p95 under 0.6·SLO → exact resumes.
        for _ in 0..64 {
            r.observe_latency(1e-3);
        }
        assert!(!r.approx_mode());
        assert_eq!(r.mode_switches(), 2);
        let exact_cost = {
            let routed = r.route(2e6, &b);
            routed.completion - routed.start
        };
        assert!(
            approx_cost < exact_cost,
            "approx service {approx_cost} should beat exact {exact_cost}"
        );
    }

    #[test]
    fn zero_slo_keeps_routing_bit_identical() {
        let run = |configure: bool| {
            let mut r = router(0.0);
            if configure {
                // serve_slo_ms defaults to 0 — the lever stays disarmed.
                r.configure_slo(&crate::config::SlideConfig::default());
                for _ in 0..100 {
                    r.observe_latency(123.0);
                }
            }
            let b = batch(32, 32 * 12);
            (0..50).map(|i| r.route(i as f64 * 1e-3, &b)).collect::<Vec<_>>()
        };
        assert_eq!(run(false), run(true));
        let mut r = router(0.0);
        r.configure_slo(&crate::config::SlideConfig::default());
        for _ in 0..100 {
            r.observe_latency(123.0);
        }
        assert!(!r.approx_mode());
        assert_eq!(r.mode_switches(), 0);
    }

    #[test]
    fn deterministic_with_zero_jitter() {
        let run = || {
            let mut r = router(0.0);
            let b = batch(32, 32 * 12);
            (0..50).map(|i| r.route(i as f64 * 1e-3, &b)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}

//! Speed-aware micro-batch routing over the device roster.
//!
//! Each active device holds an inference replica (a clone of the current
//! snapshot `Arc` — pointer, not parameters), and admitted batches route
//! with the *same rule training uses for dynamic dispatch*: the batch goes
//! to the active device with the earliest virtual free time, ties broken
//! toward the lower id. Faster devices therefore drain more batches per
//! second, exactly proportional to their relative throughput — no static
//! partitioning, no weights to tune.
//!
//! Pool churn (`[serve] events` through [`DevicePool::begin_mega_batch`])
//! shrinks or grows serving capacity live: [`Router::set_active`] only
//! affects *future* routing decisions, so batches already dispatched to a
//! removed device drain to completion — every admitted request is answered
//! exactly once across churn.

use crate::coordinator::dispatch::next_free_device;
use crate::data::PaddedBatch;
use crate::runtime::{CostModel, SimDevice};

/// Outcome of routing one micro-batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Routed {
    /// Device (global roster id) that serves the batch.
    pub device: usize,
    /// Virtual time service starts (>= formation time; queueing shows up
    /// as `start − formed_at`).
    pub start: f64,
    /// Virtual completion time.
    pub completion: f64,
}

/// Earliest-free routing over the roster's heterogeneity model.
pub struct Router {
    devices: Vec<SimDevice>,
    free_time: Vec<f64>,
    active: Vec<usize>,
    /// Roster-indexed membership mask mirroring `active` (the dispatch-rule
    /// eligibility predicate).
    active_mask: Vec<bool>,
    cost: CostModel,
    routed: Vec<u64>,
}

impl Router {
    /// `devices` is the full roster ([`DevicePool::roster`]); `active` the
    /// initially-active subset.
    pub fn new(devices: Vec<SimDevice>, active: Vec<usize>, cost: CostModel) -> Router {
        assert!(!devices.is_empty());
        let n = devices.len();
        let mut r = Router {
            devices,
            free_time: vec![0.0; n],
            active: Vec::new(),
            active_mask: vec![false; n],
            cost,
            routed: vec![0; n],
        };
        r.set_active(&active);
        r
    }

    /// Apply a pool-membership (or fleet-lease) change. In-flight work on
    /// departed devices drains (their `free_time` stays); only future
    /// routing changes. Under the fleet scheduler the serve lane calls this
    /// with its *leased* device set, so serving capacity is whatever the
    /// arbiter granted — not the raw roster.
    pub fn set_active(&mut self, ids: &[usize]) {
        assert!(!ids.is_empty(), "serving needs at least one active device");
        assert!(ids.iter().all(|&d| d < self.devices.len()), "active id outside roster");
        self.active = ids.to_vec();
        self.active_mask.fill(false);
        for &d in ids {
            self.active_mask[d] = true;
        }
    }

    pub fn active(&self) -> &[usize] {
        &self.active
    }

    /// Route one batch at time `now`: earliest-free active device wins
    /// (training's dynamic-dispatch rule, shared via
    /// `coordinator::dispatch`), then its virtual clock advances by the
    /// heterogeneity-modeled inference duration.
    pub fn route(&mut self, now: f64, batch: &PaddedBatch) -> Routed {
        let device = next_free_device(&self.free_time, now, |d| self.active_mask[d])
            .expect("router has an active device");
        let start = self.free_time[device].max(now);
        let completion = start + self.devices[device].infer_duration(&self.cost, batch);
        self.free_time[device] = completion;
        self.routed[device] += 1;
        Routed { device, start, completion }
    }

    /// Batches routed per roster device so far.
    pub fn routed(&self) -> &[u64] {
        &self.routed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn batch(bucket: usize, nnz: usize) -> PaddedBatch {
        let mut b = PaddedBatch::with_shape(bucket, 4, 2);
        b.valid = bucket;
        b.nnz = nnz;
        b
    }

    fn router(jitter: f64) -> Router {
        let cfg = DeviceConfig { jitter, ..Default::default() }; // factors 1.0..1.32
        Router::new(SimDevice::fleet(&cfg), vec![0, 1, 2, 3], CostModel::default())
    }

    #[test]
    fn faster_devices_serve_more_batches() {
        let mut r = router(0.0);
        let b = batch(32, 32 * 12);
        let mut last_completion = 0.0f64;
        for _ in 0..400 {
            last_completion = r.route(0.0, &b).completion.max(last_completion);
        }
        let routed = r.routed().to_vec();
        assert_eq!(routed.iter().sum::<u64>(), 400);
        assert!(routed[0] > routed[3], "fastest beats slowest: {routed:?}");
        // Share tracks relative speed (1.32 gap ⇒ roughly 32% more work).
        let ratio = routed[0] as f64 / routed[3] as f64;
        assert!((1.2..1.5).contains(&ratio), "throughput ratio {ratio}");
        assert!(last_completion > 0.0);
    }

    #[test]
    fn idle_routing_starts_at_now_and_queues_stack() {
        let mut r = router(0.0);
        let b = batch(16, 16 * 12);
        let first = r.route(5.0, &b);
        assert_eq!(first.start, 5.0, "idle device starts at the request time");
        // Saturate device 0 (all four then one more).
        for _ in 0..3 {
            r.route(5.0, &b);
        }
        let queued = r.route(5.0, &b);
        assert!(queued.start > 5.0, "fifth batch queues behind the first round");
        assert!(queued.completion > queued.start);
    }

    #[test]
    fn churn_only_affects_future_routing() {
        let mut r = router(0.0);
        let b = batch(32, 32 * 12);
        for _ in 0..8 {
            r.route(0.0, &b);
        }
        let before = r.routed().to_vec();
        r.set_active(&[1, 2]);
        for _ in 0..10 {
            r.route(1.0, &b);
        }
        let after = r.routed().to_vec();
        assert_eq!(after[0], before[0], "removed device gets no new work");
        assert_eq!(after[3], before[3]);
        assert_eq!(after[1] + after[2] - before[1] - before[2], 10);
        // Re-adding resumes routing to the whole fleet.
        r.set_active(&[0, 1, 2, 3]);
        for _ in 0..4 {
            r.route(50.0, &b);
        }
        assert!(r.routed()[0] > before[0]);
    }

    #[test]
    fn deterministic_with_zero_jitter() {
        let run = || {
            let mut r = router(0.0);
            let b = batch(32, 32 * 12);
            (0..50).map(|i| r.route(i as f64 * 1e-3, &b)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}

//! Minimal JSON parser + writer (offline replacement for `serde_json`).
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`) and for
//! structured metrics logs. Supports the full JSON grammar except for
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII artifacts);
//! numbers are parsed as f64 with integer accessors.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                Some(n as i64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|n| usize::try_from(n).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn int(n: i64) -> Json {
        Json::Num(n as f64)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{it}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(j.get("c"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn round_trips() {
        let src = r#"{"dims":{"classes":1024,"features":8192},"buckets":[16,24],"ok":true,"f":1.5,"s":"a\"b"}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn integer_accessors() {
        let j = Json::parse("[42, 42.5]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_i64(), Some(42));
        assert_eq!(a[0].as_usize(), Some(42));
        assert_eq!(a[1].as_i64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest() {
        // Shape of the manifest aot.py emits.
        let src = r#"{
          "buckets": [16, 24, 32],
          "config_hash": "abc123",
          "dims": {"classes": 1024, "features": 8192, "hidden": 64,
                   "max_labels": 8, "max_nnz": 32},
          "eval_batch": 256,
          "files": {"eval": "eval.hlo.txt", "step": {"16": "step_b16.hlo.txt"}},
          "version": 2
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("dims").get("features").as_usize(), Some(8192));
        assert_eq!(j.get("files").get("step").get("16").as_str(), Some("step_b16.hlo.txt"));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        let j = Json::Str("control\u{0001}".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}

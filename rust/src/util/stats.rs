//! Streaming statistics, percentiles and EWMA used by metrics and benches.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1); 0 for fewer than two observations.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Exponentially-weighted moving average (the scheduler's speed estimator).
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }
}

/// Percentile with linear interpolation (sorts a copy; fine for bench sizes).
///
/// Returns `f64::NAN` for an empty slice — serving-telemetry windows with
/// zero completed requests are a normal state, not a caller bug, and NaN
/// renders as "NaN"/`null` in tables and JSON instead of panicking.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Percentile of the values whose timestamps fall inside the trailing
/// window `(end - span, end]` — end-inclusive, so a sample landing exactly
/// on a window boundary belongs to the window that *closes* there.
///
/// `events` are `(timestamp, value)` pairs in any order. This is the single
/// definition of a "windowed quantile" shared by the serving-latency
/// telemetry ([`crate::serve::latency`]) and the fleet arbiter's SLO-breach
/// detector ([`crate::fleet::arbiter`]), so the two can never disagree on
/// what a p95 breach means. NaN when no event falls in the window (same
/// contract as [`percentile`] on an empty slice).
pub fn trailing_percentile(events: &[(f64, f64)], end: f64, span: f64, p: f64) -> f64 {
    assert!(span > 0.0, "trailing window span must be positive");
    let start = end - span;
    let values: Vec<f64> = events
        .iter()
        .filter(|&&(t, _)| t > start && t <= end)
        .map(|&(_, v)| v)
        .collect();
    percentile(&values, p)
}

/// [`trailing_percentile`] over events pre-sorted by timestamp: the same
/// `(end - span, end]` window resolved by binary search instead of a full
/// scan — what per-window telemetry uses when it folds many windows over
/// one event list. The two functions agree by construction (pinned by a
/// test below); keep any semantic change in both.
pub fn trailing_percentile_sorted(events: &[(f64, f64)], end: f64, span: f64, p: f64) -> f64 {
    assert!(span > 0.0, "trailing window span must be positive");
    debug_assert!(events.windows(2).all(|w| w[0].0 <= w[1].0), "events must be time-sorted");
    let start = end - span;
    let lo = events.partition_point(|&(t, _)| t <= start);
    let hi = events.partition_point(|&(t, _)| t <= end);
    let values: Vec<f64> = events[lo..hi].iter().map(|&(_, v)| v).collect();
    percentile(&values, p)
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

pub fn min(samples: &[f64]) -> f64 {
    samples.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(samples: &[f64]) -> f64 {
    samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// L2 norm of a vector (used for the perturbation regularization gate).
pub fn l2_norm(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0_f64).powi(2)).sum::<f64>() / 4.0;
        assert!((w.var() - direct_var).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        for _ in 0..50 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_first_value_is_exact() {
        let mut e = Ewma::new(0.1);
        e.push(7.0);
        assert_eq!(e.get(), Some(7.0));
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_of_empty_slice_is_nan() {
        assert!(percentile(&[], 50.0).is_nan());
        assert!(percentile(&[], 0.0).is_nan());
        assert!(percentile(&[], 99.0).is_nan());
    }

    #[test]
    fn percentile_of_single_element_is_that_element() {
        for p in [0.0, 10.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.5], p), 7.5);
        }
    }

    #[test]
    fn trailing_percentile_is_end_inclusive_start_exclusive() {
        let events = [(0.25, 10.0), (0.30, 20.0), (0.50, 30.0), (0.75, 40.0)];
        // Window (0.25, 0.50]: the 0.25 sample is excluded, 0.50 included.
        assert!((trailing_percentile(&events, 0.50, 0.25, 50.0) - 25.0).abs() < 1e-12);
        assert_eq!(trailing_percentile(&events, 0.50, 0.25, 100.0), 30.0);
        // Empty window -> NaN, matching percentile([]) semantics.
        assert!(trailing_percentile(&events, 1.5, 0.25, 95.0).is_nan());
        // A span covering everything reproduces the plain percentile.
        assert_eq!(
            trailing_percentile(&events, 1.0, 10.0, 100.0),
            percentile(&[10.0, 20.0, 30.0, 40.0], 100.0)
        );
    }

    #[test]
    fn sorted_variant_agrees_with_the_scan() {
        let events = [(0.1, 5.0), (0.25, 10.0), (0.25, 12.0), (0.5, 30.0), (0.9, 7.0)];
        for (end, span) in [(0.25, 0.25), (0.5, 0.25), (0.9, 0.5), (2.0, 0.5), (0.5, 10.0)] {
            for p in [0.0, 50.0, 95.0, 100.0] {
                let a = trailing_percentile(&events, end, span, p);
                let b = trailing_percentile_sorted(&events, end, span, p);
                assert!(
                    a == b || (a.is_nan() && b.is_nan()),
                    "end={end} span={span} p={p}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn l2_norm_basic() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(l2_norm(&[]), 0.0);
    }
}

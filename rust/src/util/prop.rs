//! Miniature property-testing harness with shrinking (proptest replacement).
//!
//! Coordinator invariants (routing conservation, batch bounds, merge-weight
//! normalization) are checked over randomized inputs. On failure the input
//! is shrunk toward a minimal counterexample before panicking, so test
//! output stays actionable.
//!
//! ```ignore
//! prop::check(100, seed, gen_vec_len(1..9), |case| {
//!     // return Err(msg) to fail
//! });
//! ```

use super::rng::Rng;

/// A generator produces a random case and can propose smaller variants.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate shrinks, in decreasing preference. Default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `n` random cases; shrink + panic on the first failure.
pub fn check<G: Gen>(
    n: usize,
    seed: u64,
    gen: G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case_idx in 0..n {
        let case = gen.generate(&mut rng);
        if let Err(msg) = prop(&case) {
            // Greedy shrink loop.
            let mut best = case;
            let mut best_msg = msg;
            'outer: loop {
                for cand in gen.shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case_idx}, seed {seed}): {best_msg}\nminimal counterexample: {best:?}"
            );
        }
    }
}

/// Generator: u64 in [lo, hi), shrinking toward lo.
pub struct U64Range {
    pub lo: u64,
    pub hi: u64,
}

impl Gen for U64Range {
    type Value = u64;

    fn generate(&self, rng: &mut Rng) -> u64 {
        self.lo + rng.below(self.hi - self.lo)
    }

    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Generator: Vec<u64> with length in [min_len, max_len) and items in
/// [item_lo, item_hi); shrinks by halving the vector and lowering items.
pub struct VecU64 {
    pub min_len: usize,
    pub max_len: usize,
    pub item_lo: u64,
    pub item_hi: u64,
}

impl Gen for VecU64 {
    type Value = Vec<u64>;

    fn generate(&self, rng: &mut Rng) -> Vec<u64> {
        let len = rng.range(self.min_len, self.max_len);
        (0..len).map(|_| self.item_lo + rng.below(self.item_hi - self.item_lo)).collect()
    }

    fn shrink(&self, v: &Vec<u64>) -> Vec<Vec<u64>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2.max(self.min_len)].to_vec());
            let mut shorter = v.clone();
            shorter.pop();
            out.push(shorter);
        }
        // Lower the largest element.
        if let Some((i, &m)) = v.iter().enumerate().max_by_key(|(_, &x)| x) {
            if m > self.item_lo {
                let mut lowered = v.clone();
                lowered[i] = self.item_lo + (m - self.item_lo) / 2;
                out.push(lowered);
            }
        }
        out.retain(|c| c.len() >= self.min_len);
        out
    }
}

/// Generator: Vec<f64> in [lo, hi).
pub struct VecF64 {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f64,
    pub hi: f64,
}

impl Gen for VecF64 {
    type Value = Vec<f64>;

    fn generate(&self, rng: &mut Rng) -> Vec<f64> {
        let len = rng.range(self.min_len, self.max_len);
        (0..len).map(|_| self.lo + rng.f64() * (self.hi - self.lo)).collect()
    }

    fn shrink(&self, v: &Vec<f64>) -> Vec<Vec<f64>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            let mut shorter = v.clone();
            shorter.pop();
            out.push(shorter);
        }
        out
    }
}

/// Pair generator combinator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, 1, U64Range { lo: 0, hi: 100 }, |&v| {
            if v < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let r = std::panic::catch_unwind(|| {
            check(200, 2, U64Range { lo: 0, hi: 1000 }, |&v| {
                if v < 500 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 500"))
                }
            });
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        // The shrinker should have reduced the counterexample to exactly 500.
        assert!(msg.contains("500"), "{msg}");
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let gen = VecU64 { min_len: 1, max_len: 10, item_lo: 5, item_hi: 15 };
        check(100, 3, gen, |v| {
            if v.is_empty() || v.len() >= 10 {
                return Err(format!("len {}", v.len()));
            }
            if v.iter().any(|&x| !(5..15).contains(&x)) {
                return Err("item out of range".into());
            }
            Ok(())
        });
    }
}

//! Measurement harness for `benches/` (offline replacement for criterion).
//!
//! Plain-binary benches (`harness = false`) call [`bench_fn`] for hot-path
//! micro-measurements and use [`Table`] to print paper-style rows. Designed
//! for reproducibility: fixed warmup, robust summary (median + IQR), and a
//! `HS_FULL=1` escape hatch the figure benches use to switch from CI-sized
//! to full-scale runs.

use std::time::Instant;

use super::stats;

/// Result of a micro-benchmark run.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10}  median {:>12}  p10 {:>12}  p90 {:>12}",
            self.name,
            format!("n={}", self.iters),
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: `warmup` untimed iterations then `iters` timed ones.
/// The closure's return value is black-boxed to keep the optimizer honest.
pub fn bench_fn<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    // `stats::percentile` returns NaN on empty input; a zero-iteration bench
    // would silently record NaN into the baseline JSONs, so reject it here.
    assert!(iters >= 1, "bench_fn('{name}') needs at least one timed iteration");
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        median_ns: stats::percentile(&samples, 50.0),
        p10_ns: stats::percentile(&samples, 10.0),
        p90_ns: stats::percentile(&samples, 90.0),
        mean_ns: stats::mean(&samples),
    }
}

/// `HS_FULL=1` switches figure benches from fast CI defaults to full runs.
pub fn full_scale() -> bool {
    std::env::var("HS_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let line: Vec<String> = self
            .headers
            .iter()
            .zip(&self.widths)
            .map(|(h, w)| format!("{h:<w$}"))
            .collect();
        println!("{}", line.join("  "));
        println!("{}", self.widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .zip(&self.widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            println!("{}", line.join("  "));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_fn("spin", 2, 16, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p10_ns <= r.median_ns && r.median_ns <= r.p90_ns);
        assert_eq!(r.iters, 16);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()]);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2_000_000_000.0).contains(" s"));
    }
}

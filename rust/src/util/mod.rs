//! In-tree substrates that would normally come from crates.io.
//!
//! The build is fully offline and only the `xla` crate's dependency closure
//! is vendored, so the usual ecosystem crates (rand, serde, clap, criterion,
//! proptest, …) are unavailable. Everything the coordinator needs is
//! implemented here, tested, and kept deliberately small:
//!
//! * [`rng`] — SplitMix64 seeding + xoshiro256** PRNG with normal / Zipf /
//!   log-normal samplers (rand replacement).
//! * [`json`] — JSON value model, parser and writer (serde_json replacement;
//!   used for the artifact manifest and metrics logs).
//! * [`stats`] — streaming mean/variance, percentiles, EWMA.
//! * [`bench`] — measurement harness used by `benches/` (criterion
//!   replacement): warmup, timed iterations, robust summary.
//! * [`prop`] — miniature property-testing harness with shrinking
//!   (proptest replacement) used for coordinator invariants.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

//! Deterministic PRNG + samplers (offline replacement for `rand`).
//!
//! xoshiro256** seeded through SplitMix64, plus the distributions the data
//! generator and heterogeneity model need: uniform, normal (Box–Muller),
//! log-normal, Zipf (power-law rank sampling via rejection-free inverse-CDF
//! over a precomputed table for bounded N), and shuffling.

/// xoshiro256** — fast, high-quality, 2^256-1 period, fully deterministic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (for per-worker determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// N(mu, sigma^2).
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Log-normal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates when k
    /// is a large fraction of n, rejection otherwise).
    pub fn distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "distinct({n}, {k})");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n as u64) as usize;
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }
}

/// Zipf/power-law sampler over ranks `0..n` with exponent `s`:
/// P(rank = r) ∝ (r+1)^-s. Inverse-CDF over a precomputed cumulative table —
/// O(n) setup, O(log n) per sample, exact.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for r in 0..n {
            acc += ((r + 1) as f64).powf(-s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // partition_point: first index with cdf > u.
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut m, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            m += v;
            m2 += v * v;
        }
        m /= n as f64;
        let var = m2 / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Rng::new(5);
        let z = Zipf::new(1000, 1.1);
        let mut counts = vec![0usize; 1000];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Head must dominate the tail for a power law.
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[500..].iter().sum();
        assert!(head > tail, "head={head} tail={tail}");
        assert!(counts[0] > counts[100]);
    }

    #[test]
    fn distinct_has_no_duplicates() {
        let mut rng = Rng::new(9);
        for &(n, k) in &[(10usize, 10usize), (100, 5), (50, 40)] {
            let v = rng.distinct(n, k);
            assert_eq!(v.len(), k);
            let set: std::collections::HashSet<_> = v.iter().collect();
            assert_eq!(set.len(), k);
            assert!(v.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

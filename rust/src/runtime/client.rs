//! PJRT CPU client wrapper: compile HLO-text artifacts once, execute many.
//!
//! The step executables are compiled lazily per bucket (first use) and
//! cached, so a run that only ever touches 3 of the 15 buckets doesn't pay
//! for the rest. All plumbing between `ModelState`/`PaddedBatch` and XLA
//! literals lives here.
//!
//! `Runtime` is intentionally `!Send` (the `xla` crate's client is
//! `Rc`-based): the threaded engine constructs one `Runtime` inside each
//! GPU-manager thread, the discrete-event engine uses a single instance.
//!
//! **Feature gating:** the `xla` crate is not vendored in this offline
//! tree, so the real implementation sits behind the `pjrt` cargo feature.
//! Without it, `Runtime::load` returns an error and every caller (harness
//! auto-resolution, the PJRT integration tests) falls back to / skips to
//! the pure-Rust reference backend. The stub keeps the exact same API so
//! no call site needs cfg knowledge.
//!
//! # Invariants
//!
//! * `step` mutates `model` in place and copies outputs straight into the
//!   existing buffers — no hot-path reallocation.
//! * An executable is compiled at most once per bucket per `Runtime`
//!   (lazy compile + cache); `warmup` only changes *when*, never *whether*.
//! * The stub's `load` always fails, so a stub `Runtime` value can never
//!   exist — its methods exist purely to keep call sites compiling.

#[cfg(feature = "pjrt")]
pub use real::Runtime;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

#[cfg(feature = "pjrt")]
mod real {
    use std::cell::RefCell;
    use std::collections::BTreeMap;
    use std::path::Path;
    use std::time::{Duration, Instant};

    use anyhow::Context;

    use crate::data::PaddedBatch;
    use crate::model::ModelState;
    use crate::Result;

    use super::super::manifest::Manifest;

    pub struct Runtime {
        client: xla::PjRtClient,
        pub manifest: Manifest,
        step_exes: RefCell<BTreeMap<usize, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
        eval_exe: RefCell<Option<std::rc::Rc<xla::PjRtLoadedExecutable>>>,
        /// Cumulative wall time spent inside PJRT execute calls (perf telemetry).
        pub exec_time: RefCell<Duration>,
        pub exec_count: RefCell<u64>,
    }

    impl Runtime {
        /// Load the manifest and create the PJRT CPU client. Executables are
        /// compiled on first use; `warmup` forces specific buckets eagerly.
        pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(artifacts_dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime {
                client,
                manifest,
                step_exes: RefCell::new(BTreeMap::new()),
                eval_exe: RefCell::new(None),
                exec_time: RefCell::new(Duration::ZERO),
                exec_count: RefCell::new(0),
            })
        }

        /// Eagerly compile the given buckets (e.g. the initial batch size).
        pub fn warmup(&self, buckets: &[usize]) -> Result<()> {
            for &b in buckets {
                self.step_exe(b)?;
            }
            Ok(())
        }

        fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))
        }

        fn step_exe(&self, bucket: usize) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.step_exes.borrow().get(&bucket) {
                return Ok(exe.clone());
            }
            let path = self.manifest.step_path(bucket)?;
            let exe = std::rc::Rc::new(self.compile_file(&path)?);
            self.step_exes.borrow_mut().insert(bucket, exe.clone());
            Ok(exe)
        }

        fn eval_exe(&self) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.eval_exe.borrow().as_ref() {
                return Ok(exe.clone());
            }
            let exe = std::rc::Rc::new(self.compile_file(&self.manifest.eval_path())?);
            *self.eval_exe.borrow_mut() = Some(exe.clone());
            Ok(exe)
        }

        /// Number of compiled step executables (telemetry).
        pub fn compiled_buckets(&self) -> usize {
            self.step_exes.borrow().len()
        }

        /// Execute one SGD step on `model` in place; returns (loss, exec wall time).
        ///
        /// `batch.bucket` selects the executable; the model buffers are uploaded,
        /// the updated parameters downloaded back into `model`. (Buffer-resident
        /// parameters via `execute_b` are used on the perf-optimized path — see
        /// `step_on_device`.)
        pub fn step(
            &self,
            model: &mut ModelState,
            batch: &PaddedBatch,
            lr: f32,
        ) -> Result<(f32, Duration)> {
            let exe = self.step_exe(batch.bucket)?;
            let d = &self.manifest.dims;
            batch.shape_checks(d);
            let (f, h, c) = (d.features as i64, d.hidden as i64, d.classes as i64);
            let (bk, k, l) = (batch.bucket as i64, d.max_nnz as i64, d.max_labels as i64);

            let args: Vec<xla::Literal> = vec![
                lit_f32(&model.w1, &[f, h]),
                lit_f32(&model.b1, &[h]),
                lit_f32(&model.w2, &[h, c]),
                lit_f32(&model.b2, &[c]),
                lit_i32(&batch.idx, &[bk, k]),
                lit_f32(&batch.val, &[bk, k]),
                lit_i32(&batch.lab, &[bk, l]),
                lit_f32(&batch.lab_w, &[bk, l]),
                lit_f32(&batch.smask, &[bk]),
                xla::Literal::scalar(lr),
            ];

            let t0 = Instant::now();
            let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let dt = t0.elapsed();
            *self.exec_time.borrow_mut() += dt;
            *self.exec_count.borrow_mut() += 1;

            let mut outs = result.to_tuple()?;
            anyhow::ensure!(outs.len() == 5, "step executable returned {} outputs, want 5", outs.len());
            // Copy straight into the existing model buffers — no reallocation on
            // the hot path.
            let loss = outs.pop().unwrap().to_vec::<f32>()?[0];
            outs.pop().unwrap().copy_raw_to(&mut model.b2)?;
            outs.pop().unwrap().copy_raw_to(&mut model.w2)?;
            outs.pop().unwrap().copy_raw_to(&mut model.b1)?;
            outs.pop().unwrap().copy_raw_to(&mut model.w1)?;
            Ok((loss, dt))
        }

        /// Forward-only evaluation: top-1 class per row.
        pub fn eval(&self, model: &ModelState, batch: &PaddedBatch) -> Result<Vec<i32>> {
            anyhow::ensure!(
                batch.bucket == self.manifest.eval_batch,
                "eval batch bucket {} != artifact eval batch {}",
                batch.bucket,
                self.manifest.eval_batch
            );
            let exe = self.eval_exe()?;
            let d = &self.manifest.dims;
            let (f, h, c) = (d.features as i64, d.hidden as i64, d.classes as i64);
            let (bk, k) = (batch.bucket as i64, d.max_nnz as i64);
            let args: Vec<xla::Literal> = vec![
                lit_f32(&model.w1, &[f, h]),
                lit_f32(&model.b1, &[h]),
                lit_f32(&model.w2, &[h, c]),
                lit_f32(&model.b2, &[c]),
                lit_i32(&batch.idx, &[bk, k]),
                lit_f32(&batch.val, &[bk, k]),
            ];
            let t0 = Instant::now();
            let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            *self.exec_time.borrow_mut() += t0.elapsed();
            *self.exec_count.borrow_mut() += 1;
            let preds = result.to_tuple1()?;
            Ok(preds.to_vec::<i32>()?)
        }
    }

    // Hot-path literal constructors. `create_from_shape_and_untyped_data` is a
    // single memcpy into a pre-shaped literal; the obvious `vec1(..).reshape(..)`
    // costs ~7x more (measured 4.3ms vs 0.6ms for the (8192,64) W1 — see
    // EXPERIMENTS.md §Perf) because reshape runs a full C++ relayout.
    fn lit_f32(data: &[f32], dims: &[i64]) -> xla::Literal {
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        let dims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, &dims, bytes)
            .expect("f32 literal creation cannot fail for matching element count")
    }

    fn lit_i32(data: &[i32], dims: &[i64]) -> xla::Literal {
        debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
        let dims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
        };
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, &dims, bytes)
            .expect("i32 literal creation cannot fail for matching element count")
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::cell::RefCell;
    use std::path::Path;
    use std::time::Duration;

    use anyhow::bail;

    use crate::data::PaddedBatch;
    use crate::model::ModelState;
    use crate::Result;

    use super::super::manifest::Manifest;

    /// API-compatible stand-in for the PJRT runtime when the `pjrt` feature
    /// (and with it the `xla` crate) is absent. `load` always fails, so a
    /// value of this type can never actually exist — the methods only keep
    /// call sites compiling.
    pub struct Runtime {
        /// Typed view of `artifacts/manifest.json` (never populated in the
        /// stub — see the type docs).
        pub manifest: Manifest,
        /// Cumulative wall time inside PJRT execute calls (always zero).
        pub exec_time: RefCell<Duration>,
        /// Number of PJRT execute calls (always zero).
        pub exec_count: RefCell<u64>,
    }

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` cargo feature (the `xla` crate \
         is not vendored offline); use the reference backend";

    impl Runtime {
        /// Always fails: the `pjrt` feature (and the `xla` crate) is absent.
        pub fn load(_artifacts_dir: &Path) -> Result<Runtime> {
            bail!(UNAVAILABLE);
        }

        /// Unreachable in practice (`load` never succeeds).
        pub fn warmup(&self, _buckets: &[usize]) -> Result<()> {
            bail!(UNAVAILABLE);
        }

        /// Number of compiled step executables — zero, nothing compiles.
        pub fn compiled_buckets(&self) -> usize {
            0
        }

        /// Unreachable in practice (`load` never succeeds).
        pub fn step(
            &self,
            _model: &mut ModelState,
            _batch: &PaddedBatch,
            _lr: f32,
        ) -> Result<(f32, Duration)> {
            bail!(UNAVAILABLE);
        }

        /// Unreachable in practice (`load` never succeeds).
        pub fn eval(&self, _model: &ModelState, _batch: &PaddedBatch) -> Result<Vec<i32>> {
            bail!(UNAVAILABLE);
        }
    }
}

//! PJRT runtime: load the AOT HLO-text artifacts and execute them, plus the
//! simulated heterogeneous device fleet that stands in for the paper's
//! 4× V100 server.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json`.
//! * [`client`] — PJRT CPU client wrapper: per-bucket step executables,
//!   the eval executable, literal plumbing. One instance per thread (the
//!   `xla` crate's client is `Rc`-based, i.e. `!Send` — each GPU-manager
//!   thread owns its own client, which also mirrors the paper's
//!   one-manager-per-GPU design).
//! * [`device`] — heterogeneity model: persistent speed factor + AR(1)
//!   jitter + nnz sensitivity + scripted drift multipliers
//!   (`[calibration] events`), with real-sleep and virtual-clock modes.
//! * [`cost`] — analytic step-cost model, calibratable against real PJRT
//!   measurements; drives the discrete-event engine and is the nominal
//!   reference the online calibration plane ([`crate::tuning`]) fits
//!   per-device multipliers against.

pub mod client;
pub mod cost;
pub mod device;
pub mod manifest;

pub use client::Runtime;
pub use cost::CostModel;
pub use device::SimDevice;
pub use manifest::Manifest;

//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the contract between the build-time Python world and the
//! run-time Rust world: model dimensions, the batch-size bucket grid, file
//! names, and the executable I/O layouts. `Manifest::load` validates
//! structure; `Manifest::check_config` validates agreement with the run
//! config before any training starts.
//!
//! # Invariants
//!
//! * A loaded manifest's `buckets` are non-empty and strictly increasing,
//!   and every referenced HLO file existed at load time — `load` rejects
//!   anything else, so downstream code never re-validates.
//! * `check_config` passing means dims and the bucket grid agree exactly
//!   with the run config; a mismatch is a hard error, never a fallback.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::config::{Config, ModelDims};
use crate::util::json::Json;
use crate::Result;

/// Typed, validated view of one `artifacts/` directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Model dimensions the artifacts were AOT-lowered for.
    pub dims: ModelDims,
    /// Batch-size bucket grid (strictly increasing, one executable each).
    pub buckets: Vec<usize>,
    /// Grid minimum (must equal `buckets[0]`).
    pub b_min: usize,
    /// Grid maximum (must equal `buckets.last()`).
    pub b_max: usize,
    /// Grid pitch (Algorithm 1's β).
    pub beta: usize,
    /// The single evaluation batch size the eval executable was built for.
    pub eval_batch: usize,
    /// Hash of the AOT config (provenance; empty when absent).
    pub config_hash: String,
    /// bucket -> HLO file name.
    pub step_files: Vec<(usize, String)>,
    /// Eval executable's HLO file name.
    pub eval_file: String,
}

impl Manifest {
    /// Load and structurally validate `dir/manifest.json` (see the module
    /// docs for what "valid" guarantees).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first (python never runs on the training path, \
                 but the AOT artifacts must exist)",
                path.display()
            )
        })?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let dims_j = j.get("dims");
        let dim = |k: &str| -> Result<usize> {
            dims_j.get(k).as_usize().with_context(|| format!("manifest dims.{k} missing"))
        };
        let dims = ModelDims {
            features: dim("features")?,
            hidden: dim("hidden")?,
            classes: dim("classes")?,
            max_nnz: dim("max_nnz")?,
            max_labels: dim("max_labels")?,
        };

        let buckets: Vec<usize> = j
            .get("buckets")
            .as_arr()
            .context("manifest buckets missing")?
            .iter()
            .map(|v| v.as_usize().context("bucket must be an integer"))
            .collect::<Result<_>>()?;
        if buckets.is_empty() {
            bail!("manifest has no buckets");
        }
        if !buckets.windows(2).all(|w| w[0] < w[1]) {
            bail!("manifest buckets must be strictly increasing");
        }

        let steps_j = j.get("files").get("step");
        let steps_obj = steps_j.as_obj().context("manifest files.step missing")?;
        let mut step_files = Vec::with_capacity(buckets.len());
        for &b in &buckets {
            let name = steps_obj
                .get(&b.to_string())
                .and_then(|v| v.as_str())
                .with_context(|| format!("manifest missing step file for bucket {b}"))?;
            let full = dir.join(name);
            if !full.exists() {
                bail!("manifest references missing file {}", full.display());
            }
            step_files.push((b, name.to_string()));
        }
        let eval_file = j
            .get("files")
            .get("eval")
            .as_str()
            .context("manifest files.eval missing")?
            .to_string();
        if !dir.join(&eval_file).exists() {
            bail!("manifest references missing eval file {eval_file}");
        }

        let get_usize =
            |k: &str| -> Result<usize> { j.get(k).as_usize().with_context(|| format!("manifest {k} missing")) };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            dims,
            b_min: get_usize("b_min")?,
            b_max: get_usize("b_max")?,
            beta: get_usize("beta")?,
            eval_batch: get_usize("eval_batch")?,
            config_hash: j.get("config_hash").as_str().unwrap_or("").to_string(),
            buckets,
            step_files,
            eval_file,
        })
    }

    /// Fail fast if the run config disagrees with what was AOT-compiled.
    pub fn check_config(&self, cfg: &Config) -> Result<()> {
        if self.dims != cfg.model {
            bail!(
                "artifact dims {:?} != config dims {:?}; re-run `make artifacts` with matching flags",
                self.dims,
                cfg.model
            );
        }
        let grid = cfg.bucket_grid();
        if grid != self.buckets {
            bail!(
                "artifact bucket grid {:?} != config grid {:?} (b_min/b_max/beta mismatch)",
                self.buckets,
                grid
            );
        }
        Ok(())
    }

    /// Path of the step executable for `bucket` (error off the grid).
    pub fn step_path(&self, bucket: usize) -> Result<PathBuf> {
        self.step_files
            .iter()
            .find(|(b, _)| *b == bucket)
            .map(|(_, name)| self.dir.join(name))
            .with_context(|| format!("no step artifact for bucket {bucket}"))
    }

    /// Path of the eval executable.
    pub fn eval_path(&self) -> PathBuf {
        self.dir.join(&self.eval_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path, buckets: &[usize]) {
        std::fs::create_dir_all(dir).unwrap();
        let steps: Vec<String> = buckets
            .iter()
            .map(|b| {
                let name = format!("step_b{b}.hlo.txt");
                std::fs::write(dir.join(&name), "HloModule fake").unwrap();
                format!("\"{b}\": \"{name}\"")
            })
            .collect();
        std::fs::write(dir.join("eval.hlo.txt"), "HloModule fake").unwrap();
        let buckets_s: Vec<String> = buckets.iter().map(|b| b.to_string()).collect();
        let manifest = format!(
            r#"{{
              "version": 2, "config_hash": "deadbeef",
              "dims": {{"features": 8192, "hidden": 64, "classes": 1024,
                        "max_nnz": 32, "max_labels": 8}},
              "buckets": [{}], "b_min": {}, "b_max": {}, "beta": 8,
              "eval_batch": 256,
              "files": {{"eval": "eval.hlo.txt", "step": {{{}}}}}
            }}"#,
            buckets_s.join(","),
            buckets[0],
            buckets[buckets.len() - 1],
            steps.join(",")
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn loads_and_validates() {
        let dir = std::env::temp_dir().join("hs-manifest-test");
        let _ = std::fs::remove_dir_all(&dir);
        write_fake_manifest(&dir, &[16, 24, 32]);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.dims.features, 8192);
        assert_eq!(m.buckets, vec![16, 24, 32]);
        assert!(m.step_path(24).unwrap().exists());
        assert!(m.step_path(99).is_err());
    }

    #[test]
    fn missing_file_detected() {
        let dir = std::env::temp_dir().join("hs-manifest-test2");
        let _ = std::fs::remove_dir_all(&dir);
        write_fake_manifest(&dir, &[16]);
        std::fs::remove_file(dir.join("step_b16.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn config_mismatch_detected() {
        let dir = std::env::temp_dir().join("hs-manifest-test3");
        let _ = std::fs::remove_dir_all(&dir);
        write_fake_manifest(&dir, &[16, 24, 32]);
        let m = Manifest::load(&dir).unwrap();
        let cfg = crate::config::Config::default(); // grid 16..128 — mismatch
        assert!(m.check_config(&cfg).is_err());
    }
}

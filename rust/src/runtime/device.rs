//! Simulated heterogeneous accelerators — the Fig. 1 substitute.
//!
//! The paper measures a ~32% fastest↔slowest gap across *identical* V100s
//! (clock/memory oscillation) amplified by sparse-batch cardinality
//! variation. Here every virtual device wraps the same PJRT CPU executable
//! with:
//!
//! * a **persistent speed factor** (config `devices.speed_factors`),
//! * **AR(1) multiplicative jitter** (slowly-wandering clock state, matching
//!   the paper's "oscillations within observable ranges"),
//! * an **nnz-sensitivity** knob scaling the cardinality-dependent term.
//!
//! Two uses: the virtual-time engine asks for a full simulated duration
//! ([`SimDevice::step_duration`]); the threaded real engine measures the
//! actual PJRT time and asks how much *extra* delay to inject
//! ([`SimDevice::stretch`]).

use crate::config::DeviceConfig;
use crate::data::PaddedBatch;
use crate::util::rng::Rng;

use super::cost::CostModel;

/// AR(1) coefficient for the jitter process: state wanders slowly across
/// steps instead of white noise, like real clock drift.
const JITTER_RHO: f64 = 0.9;

/// One simulated heterogeneous accelerator.
///
/// # Invariants
///
/// * The effective multiplier is always > 0.1 — jitter and drift can
///   slow a device arbitrarily but never stop or reverse its clock.
/// * With `jitter = 0` every duration is a deterministic function of
///   (speed factor, drift, workload); with jitter on, the trajectory is a
///   deterministic function of the config seed — runs are reproducible
///   either way.
#[derive(Clone, Debug)]
pub struct SimDevice {
    /// Global roster id.
    pub id: usize,
    /// Persistent configured slowdown factor (1.0 = nominal).
    pub speed_factor: f64,
    jitter_amp: f64,
    jitter_state: f64,
    nnz_sensitivity: f64,
    /// Scripted drift multiplier on top of the configured factor
    /// (`[calibration] events`; 1.0 = no drift). See [`SimDevice::set_drift`].
    drift: f64,
    rng: Rng,
}

impl SimDevice {
    pub fn new(id: usize, cfg: &DeviceConfig) -> Self {
        assert!(id < cfg.count);
        SimDevice {
            id,
            speed_factor: cfg.speed_factors[id],
            jitter_amp: cfg.jitter,
            jitter_state: 0.0,
            nnz_sensitivity: cfg.nnz_sensitivity,
            drift: 1.0,
            rng: Rng::new(cfg.seed ^ (id as u64).wrapping_mul(0xA5A5_5A5A_DEAD_BEEF)),
        }
    }

    /// Build the whole fleet from config.
    pub fn fleet(cfg: &DeviceConfig) -> Vec<SimDevice> {
        (0..cfg.count).map(|i| SimDevice::new(i, cfg)).collect()
    }

    /// A device outside the configured fleet (elastic hot-add spares): any
    /// id, explicit speed factor, same jitter/sensitivity/seed derivation.
    pub fn with_speed(id: usize, speed_factor: f64, cfg: &DeviceConfig) -> Self {
        SimDevice {
            id,
            speed_factor,
            jitter_amp: cfg.jitter,
            jitter_state: 0.0,
            nnz_sensitivity: cfg.nnz_sensitivity,
            drift: 1.0,
            rng: Rng::new(cfg.seed ^ (id as u64).wrapping_mul(0xA5A5_5A5A_DEAD_BEEF)),
        }
    }

    /// Set the scripted drift multiplier (thermal throttle / co-tenant
    /// contention scenarios): the device's effective slowdown becomes
    /// `speed_factor × multiplier`, jitter on top. 1.0 restores nominal.
    /// Idempotent — the engines re-apply the trace value every mega-batch.
    pub fn set_drift(&mut self, multiplier: f64) {
        assert!(multiplier > 0.0, "drift multiplier must be positive");
        self.drift = multiplier;
    }

    /// The scripted drift multiplier currently in effect.
    pub fn drift(&self) -> f64 {
        self.drift
    }

    /// Advance the jitter process and return the current multiplicative
    /// slowdown (always > 0.1).
    fn next_multiplier(&mut self) -> f64 {
        let eps = self.rng.normal() * self.jitter_amp;
        self.jitter_state = JITTER_RHO * self.jitter_state + (1.0 - JITTER_RHO) * eps;
        (self.speed_factor * self.drift * (1.0 + self.jitter_state)).max(0.1)
    }

    /// Virtual-time engine: full simulated duration (seconds) of one step.
    pub fn step_duration(&mut self, cost: &CostModel, batch: &PaddedBatch) -> f64 {
        self.step_duration_at(cost, batch, 1.0)
    }

    /// Step duration at an active-class sparsity ratio — the dense
    /// per-sample term shrinks by [`CostModel::sparsity_factor`], gather
    /// and fixed costs do not. `ratio = 1.0` multiplies by the literal
    /// `1.0`, so the exact path's clock is bit-identical to
    /// [`step_duration`](SimDevice::step_duration) (and the jitter RNG
    /// advances once either way).
    pub fn step_duration_at(&mut self, cost: &CostModel, batch: &PaddedBatch, ratio: f64) -> f64 {
        let nominal = cost.t_fixed
            + cost.t_per_nnz * batch.nnz as f64 * self.nnz_sensitivity
            + cost.t_per_sample * batch.bucket as f64 * cost.sparsity_factor(ratio);
        nominal * self.next_multiplier()
    }

    /// Threaded real engine: given the measured PJRT wall time, how long the
    /// *simulated heterogeneous device* would have taken. The worker sleeps
    /// `stretch - real` when positive.
    pub fn stretch(&mut self, real_secs: f64) -> f64 {
        real_secs * self.next_multiplier()
    }

    /// Serving plane: full simulated duration of one forward-only inference
    /// pass — same heterogeneity model as training steps, forward-fraction
    /// cost (see [`CostModel::infer_time_parts`]).
    pub fn infer_duration(&mut self, cost: &CostModel, batch: &PaddedBatch) -> f64 {
        self.infer_duration_at(cost, batch, 1.0)
    }

    /// Inference duration at an active-class sparsity ratio (approximate
    /// LSH top-k serving; `1.0` = exact, bit-identical to
    /// [`infer_duration`](SimDevice::infer_duration)).
    pub fn infer_duration_at(&mut self, cost: &CostModel, batch: &PaddedBatch, ratio: f64) -> f64 {
        let nominal = cost.t_fixed
            + cost.infer_fraction
                * (cost.t_per_nnz * batch.nnz as f64 * self.nnz_sensitivity
                    + cost.t_per_sample * batch.bucket as f64 * cost.sparsity_factor(ratio));
        nominal * self.next_multiplier()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn batch(bucket: usize, nnz: usize) -> PaddedBatch {
        PaddedBatch {
            bucket,
            valid: bucket,
            idx: vec![0; bucket],
            val: vec![0.0; bucket],
            lab: vec![0; bucket],
            lab_w: vec![0.0; bucket],
            smask: vec![1.0; bucket],
            nnz,
            sample_ids: vec![],
        }
    }

    #[test]
    fn slower_device_takes_longer_on_average() {
        let cfg = DeviceConfig::default(); // factors 1.0 .. 1.32
        let cost = CostModel::default();
        let mut fast = SimDevice::new(0, &cfg);
        let mut slow = SimDevice::new(3, &cfg);
        let b = batch(64, 64 * 12);
        let n = 500;
        let tf: f64 = (0..n).map(|_| fast.step_duration(&cost, &b)).sum();
        let ts: f64 = (0..n).map(|_| slow.step_duration(&cost, &b)).sum();
        let gap = ts / tf;
        assert!((1.25..1.45).contains(&gap), "expected ~1.32 gap, got {gap}");
    }

    #[test]
    fn nnz_increases_duration() {
        let cfg = DeviceConfig { jitter: 0.0, ..Default::default() };
        let cost = CostModel::default();
        let mut d = SimDevice::new(0, &cfg);
        let t1 = d.step_duration(&cost, &batch(64, 100));
        let t2 = d.step_duration(&cost, &batch(64, 10_000));
        assert!(t2 > t1);
    }

    #[test]
    fn zero_jitter_is_deterministic() {
        let cfg = DeviceConfig { jitter: 0.0, ..Default::default() };
        let cost = CostModel::default();
        let mut d = SimDevice::new(1, &cfg);
        let b = batch(32, 400);
        let t1 = d.step_duration(&cost, &b);
        let t2 = d.step_duration(&cost, &b);
        assert_eq!(t1, t2);
        // Exactly factor × nominal.
        let nominal = cost.t_fixed + cost.t_per_nnz * 400.0 + cost.t_per_sample * 32.0;
        assert!((t1 - nominal * cfg.speed_factors[1]).abs() < 1e-12);
    }

    #[test]
    fn jitter_wanders_but_stays_bounded() {
        let cfg = DeviceConfig { jitter: 0.05, ..Default::default() };
        let cost = CostModel::default();
        let mut d = SimDevice::new(0, &cfg);
        let b = batch(64, 500);
        let ts: Vec<f64> = (0..1000).map(|_| d.step_duration(&cost, &b)).collect();
        let mean = crate::util::stats::mean(&ts);
        for &t in &ts {
            assert!(t > 0.0);
            assert!((t / mean - 1.0).abs() < 0.5, "jitter exploded: {t} vs mean {mean}");
        }
        // It actually varies.
        assert!(crate::util::stats::max(&ts) > crate::util::stats::min(&ts));
    }

    #[test]
    fn inference_is_faster_than_training_on_the_same_device() {
        let cfg = DeviceConfig { jitter: 0.0, ..Default::default() };
        let cost = CostModel::default();
        let mut d = SimDevice::new(2, &cfg);
        let b = batch(64, 64 * 12);
        let infer = d.infer_duration(&cost, &b);
        let step = d.step_duration(&cost, &b);
        assert!(infer < step, "forward-only {infer} must undercut fwd+bwd {step}");
        // Deterministic with zero jitter and slowed by the speed factor.
        let nominal = cost.infer_time_parts(64, 64 * 12);
        assert!((infer - nominal * cfg.speed_factors[2]).abs() < 1e-12);
    }

    #[test]
    fn drift_multiplies_the_speed_factor_and_restores() {
        let cfg = DeviceConfig { jitter: 0.0, ..Default::default() };
        let cost = CostModel::default();
        let mut d = SimDevice::new(0, &cfg); // factor 1.0
        let b = batch(32, 400);
        let nominal = d.step_duration(&cost, &b);
        d.set_drift(1.8);
        assert_eq!(d.drift(), 1.8);
        let throttled = d.step_duration(&cost, &b);
        assert!((throttled - 1.8 * nominal).abs() < 1e-12, "{throttled} vs {nominal}");
        d.set_drift(1.0);
        assert_eq!(d.step_duration(&cost, &b), nominal, "recover restores nominal exactly");
    }

    #[test]
    fn sparsity_lowers_step_duration_monotonically() {
        let cfg = DeviceConfig { jitter: 0.0, ..Default::default() };
        let cost = CostModel::default();
        let mut d = SimDevice::new(0, &cfg);
        let b = batch(64, 64 * 12);
        // ratio = 1.0 is exactly the dense clock.
        assert_eq!(d.step_duration_at(&cost, &b, 1.0), d.step_duration(&cost, &b));
        let ladder = [1.0, 0.75, 0.5, 0.25, 0.05];
        let ts: Vec<f64> = ladder.iter().map(|&r| d.step_duration_at(&cost, &b, r)).collect();
        for w in ts.windows(2) {
            assert!(w[0] > w[1], "cost must fall down the ladder: {ts:?}");
        }
        let is: Vec<f64> = ladder.iter().map(|&r| d.infer_duration_at(&cost, &b, r)).collect();
        for w in is.windows(2) {
            assert!(w[0] > w[1], "infer cost must fall down the ladder: {is:?}");
        }
    }

    #[test]
    fn stretch_scales_real_time() {
        let cfg = DeviceConfig { jitter: 0.0, ..Default::default() };
        let mut d = SimDevice::new(3, &cfg);
        let s = d.stretch(0.010);
        assert!((s - 0.0132).abs() < 1e-9); // 10ms * 1.32
    }
}

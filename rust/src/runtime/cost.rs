//! Analytic per-step cost model — the virtual-time engine's clock source.
//!
//! A step over a batch `(b, nnz)` on the 3-layer sparse MLP decomposes into
//!
//! * fixed dispatch/launch overhead            `t_fixed`
//! * sparse input layer (gather-bound)          `t_nnz  * nnz`
//! * dense hidden→output fwd+bwd (FLOP-bound)   `t_dense * b`
//!
//! mirroring the paper's observation that sparse-batch cost is cardinality-
//! sensitive while the dense output layer scales with the batch size. The
//! constants default to values fitted on the CPU PJRT backend at the default
//! dims, and [`CostModel::calibrate`] refits them against live PJRT
//! measurements (least squares over a small probe grid).

use crate::data::PaddedBatch;
use crate::model::ModelState;

use super::Runtime;
use crate::Result;

/// Step-time model in seconds.
///
/// # Invariants
///
/// * Step and inference times are strictly monotone in both batch size
///   and nnz, and never below `t_fixed` — the discrete-event clock can
///   always advance.
/// * [`CostModel::calibrate`] clamps every refitted coefficient
///   non-negative, so a noisy probe can't produce negative time.
/// * This is the *nominal* model: per-device speed factors, jitter, and
///   drift multiply on top of it ([`crate::runtime::SimDevice`]), and
///   the online calibration plane ([`crate::tuning`]) estimates those
///   multipliers back from observed timings against these same terms.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Fixed dispatch/launch overhead per step (seconds).
    pub t_fixed: f64,
    /// Sparse input-layer cost per non-zero (gather-bound term).
    pub t_per_nnz: f64,
    /// Dense fwd+bwd cost per sample (FLOP-bound term).
    pub t_per_sample: f64,
    /// Per-parameter transfer cost of one model merge hop (all-reduce link).
    pub t_per_param_xfer: f64,
    /// Fixed cost of one model-merge barrier: stream setup, kernel launch,
    /// cross-device synchronization (the paper's §4 observes large kernel
    /// startup overheads that grow with the number of GPUs; merging too
    /// often is what makes gradient aggregation slow in Fig. 9).
    pub t_merge_fixed: f64,
    /// Forward-only fraction of a training step's variable cost — inference
    /// skips the backward pass (~2/3 of the FLOPs on this MLP), so the
    /// serving plane charges `t_fixed + infer_fraction × (nnz + sample)`
    /// per micro-batch.
    pub infer_fraction: f64,
    /// Share of the per-sample dense cost that does *not* shrink with the
    /// active-class sparsity ratio (hidden-layer work, LSH queries,
    /// selection bookkeeping). The remaining `1 - sparsity_floor` is
    /// output-layer work and scales linearly with the ratio — see
    /// [`CostModel::sparsity_factor`].
    pub sparsity_floor: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Fitted on the default dims (F=8192, H=64, C=1024) on CPU PJRT.
        CostModel {
            t_fixed: 300e-6,
            t_per_nnz: 40e-9,
            t_per_sample: 45e-6,
            t_per_param_xfer: 0.15e-9,
            t_merge_fixed: 4e-3,
            infer_fraction: 0.35,
            sparsity_floor: 0.1,
        }
    }
}

impl CostModel {
    /// Nominal (speed-factor-1.0) step time for a padded batch.
    pub fn step_time(&self, batch: &PaddedBatch) -> f64 {
        self.step_time_parts(batch.bucket, batch.nnz)
    }

    /// [`step_time`](CostModel::step_time) from raw (bucket, nnz) parts.
    pub fn step_time_parts(&self, bucket: usize, nnz: usize) -> f64 {
        self.step_time_parts_at(bucket, nnz, 1.0)
    }

    /// Multiplier the active-class ratio applies to the dense per-sample
    /// term: `sparsity_floor + (1 - sparsity_floor) · ratio`. Returns the
    /// literal `1.0` at `ratio >= 1.0` so the exact path's predicted cost
    /// is bit-identical to the pre-sparsity model (no float round-trip).
    pub fn sparsity_factor(&self, ratio: f64) -> f64 {
        if ratio >= 1.0 {
            1.0
        } else {
            self.sparsity_floor + (1.0 - self.sparsity_floor) * ratio.max(0.0)
        }
    }

    /// Step time at a given active-class sparsity ratio: only the dense
    /// per-sample term shrinks; gather and fixed costs are ratio-blind.
    pub fn step_time_parts_at(&self, bucket: usize, nnz: usize, ratio: f64) -> f64 {
        self.t_fixed
            + self.t_per_nnz * nnz as f64
            + self.t_per_sample * bucket as f64 * self.sparsity_factor(ratio)
    }

    /// Nominal forward-only (inference) time for a padded batch.
    pub fn infer_time(&self, batch: &PaddedBatch) -> f64 {
        self.infer_time_parts(batch.bucket, batch.nnz)
    }

    /// [`infer_time`](CostModel::infer_time) from raw (bucket, nnz) parts.
    pub fn infer_time_parts(&self, bucket: usize, nnz: usize) -> f64 {
        self.infer_time_parts_at(bucket, nnz, 1.0)
    }

    /// Inference time at a given active-class sparsity ratio (approximate
    /// LSH top-k serving).
    pub fn infer_time_parts_at(&self, bucket: usize, nnz: usize, ratio: f64) -> f64 {
        self.t_fixed
            + self.infer_fraction
                * (self.t_per_nnz * nnz as f64
                    + self.t_per_sample * bucket as f64 * self.sparsity_factor(ratio))
    }

    /// One ring/tree hop transferring `params` parameters.
    pub fn transfer_time(&self, params: usize) -> f64 {
        self.t_per_param_xfer * params as f64
    }

    /// Refit (t_fixed, t_per_nnz, t_per_sample) against live PJRT step
    /// measurements over a probe grid of buckets. Uses ordinary least
    /// squares on the 3-parameter linear model.
    pub fn calibrate(runtime: &Runtime, buckets: &[usize], reps: usize) -> Result<CostModel> {
        let dims = &runtime.manifest.dims;
        let mut model = ModelState::init(dims, 1234);
        let mut rows: Vec<[f64; 3]> = Vec::new(); // [1, nnz, bucket]
        let mut ys: Vec<f64> = Vec::new();
        let mut rng = crate::util::rng::Rng::new(99);
        for &b in buckets {
            for dense in [true, false] {
                let batch = synth_batch(dims, b, dense, &mut rng);
                // Warm the executable + caches.
                runtime.step(&mut model, &batch, 0.0)?;
                let mut best = f64::INFINITY;
                for _ in 0..reps.max(1) {
                    let (_, dt) = runtime.step(&mut model, &batch, 0.0)?;
                    best = best.min(dt.as_secs_f64());
                }
                rows.push([1.0, batch.nnz as f64, b as f64]);
                ys.push(best);
            }
        }
        let coef = least_squares_3(&rows, &ys);
        let base = CostModel::default();
        Ok(CostModel {
            t_fixed: coef[0].max(1e-6),
            t_per_nnz: coef[1].max(0.0),
            t_per_sample: coef[2].max(1e-9),
            t_per_param_xfer: base.t_per_param_xfer,
            t_merge_fixed: base.t_merge_fixed,
            infer_fraction: base.infer_fraction,
            sparsity_floor: base.sparsity_floor,
        })
    }
}

/// Random batch with either max or minimal nnz per row (spread for fitting).
fn synth_batch(
    dims: &crate::config::ModelDims,
    bucket: usize,
    dense: bool,
    rng: &mut crate::util::rng::Rng,
) -> PaddedBatch {
    let k = dims.max_nnz;
    let l = dims.max_labels;
    let per_row = if dense { k } else { (k / 8).max(1) };
    let mut b = PaddedBatch {
        bucket,
        valid: bucket,
        idx: vec![0; bucket * k],
        val: vec![0.0; bucket * k],
        lab: vec![0; bucket * l],
        lab_w: vec![0.0; bucket * l],
        smask: vec![1.0; bucket],
        nnz: bucket * per_row,
        sample_ids: (0..bucket as u32).collect(),
    };
    for r in 0..bucket {
        for j in 0..per_row {
            b.idx[r * k + j] = rng.range(0, dims.features) as i32;
            b.val[r * k + j] = rng.f32() + 0.1;
        }
        b.lab[r * l] = rng.range(0, dims.classes) as i32;
        b.lab_w[r * l] = 1.0;
    }
    b
}

/// OLS for y = c0*x0 + c1*x1 + c2*x2 via normal equations (3x3 solve).
fn least_squares_3(xs: &[[f64; 3]], ys: &[f64]) -> [f64; 3] {
    let mut ata = [[0.0f64; 3]; 3];
    let mut aty = [0.0f64; 3];
    for (x, &y) in xs.iter().zip(ys) {
        for i in 0..3 {
            for j in 0..3 {
                ata[i][j] += x[i] * x[j];
            }
            aty[i] += x[i] * y;
        }
    }
    solve3(ata, aty)
}

fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> [f64; 3] {
    // Gaussian elimination with partial pivoting.
    for col in 0..3 {
        let mut piv = col;
        for r in col + 1..3 {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        if d.abs() < 1e-18 {
            continue; // singular; leave zeros
        }
        for r in 0..3 {
            if r != col {
                let f = a[r][col] / d;
                for c in 0..3 {
                    a[r][c] -= f * a[col][c];
                }
                b[r] -= f * b[col];
            }
        }
    }
    let mut out = [0.0; 3];
    for i in 0..3 {
        out[i] = if a[i][i].abs() < 1e-18 { 0.0 } else { b[i] / a[i][i] };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_is_monotone() {
        let m = CostModel::default();
        assert!(m.step_time_parts(128, 1000) > m.step_time_parts(64, 1000));
        assert!(m.step_time_parts(64, 2000) > m.step_time_parts(64, 1000));
        assert!(m.step_time_parts(16, 0) >= m.t_fixed);
    }

    #[test]
    fn least_squares_recovers_exact_plane() {
        // y = 2 + 3*x1 + 0.5*x2, exactly.
        let xs: Vec<[f64; 3]> = (0..20)
            .map(|i| [1.0, (i % 5) as f64, (i / 5) as f64 * 10.0])
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 3.0 * x[1] + 0.5 * x[2]).collect();
        let c = least_squares_3(&xs, &ys);
        assert!((c[0] - 2.0).abs() < 1e-9, "{c:?}");
        assert!((c[1] - 3.0).abs() < 1e-9);
        assert!((c[2] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn inference_is_cheaper_than_training_but_keeps_the_fixed_cost() {
        let m = CostModel::default();
        assert!(m.infer_time_parts(128, 1000) < m.step_time_parts(128, 1000));
        assert!(m.infer_time_parts(16, 0) >= m.t_fixed);
        // Still monotone in both batch size and cardinality.
        assert!(m.infer_time_parts(128, 1000) > m.infer_time_parts(64, 1000));
        assert!(m.infer_time_parts(64, 2000) > m.infer_time_parts(64, 1000));
    }

    #[test]
    fn transfer_scales_with_params() {
        let m = CostModel::default();
        assert!(m.transfer_time(2_000_000) > m.transfer_time(1_000_000));
    }

    #[test]
    fn sparsity_ladder_is_monotone_and_exact_at_one() {
        let m = CostModel::default();
        // ratio >= 1.0 is the literal identity — the exact path's cost is
        // bit-identical to the pre-sparsity model.
        assert_eq!(m.sparsity_factor(1.0), 1.0);
        assert_eq!(m.sparsity_factor(1.5), 1.0);
        assert_eq!(
            m.step_time_parts_at(64, 1000, 1.0).to_bits(),
            m.step_time_parts(64, 1000).to_bits()
        );
        // Strictly cheaper as the ratio falls, never below the ratio-blind
        // floor (fixed + gather + sparsity_floor share of dense).
        let ladder = [1.0, 0.75, 0.5, 0.25, 0.05];
        for w in ladder.windows(2) {
            assert!(
                m.step_time_parts_at(64, 1000, w[0]) > m.step_time_parts_at(64, 1000, w[1]),
                "step cost must fall from ratio {} to {}",
                w[0],
                w[1]
            );
            assert!(m.infer_time_parts_at(64, 1000, w[0]) > m.infer_time_parts_at(64, 1000, w[1]));
        }
        let floor = m.t_fixed + m.t_per_nnz * 1000.0;
        assert!(m.step_time_parts_at(64, 1000, 0.0) > floor);
    }
}

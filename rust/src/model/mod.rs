//! Model state (parameter buffers) shared by the runtime, the coordinator's
//! merging logic, and the pure-Rust reference implementation.

pub mod checkpoint;
pub mod reference;

use crate::config::ModelDims;
use crate::util::rng::Rng;

/// Flat f32 parameter buffers for the 3-layer sparse MLP.
///
/// Layout mirrors the AOT step executable's I/O contract:
/// `w1`: row-major `[features, hidden]`, `b1`: `[hidden]`,
/// `w2`: row-major `[hidden, classes]`, `b2`: `[classes]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelState {
    pub dims: ModelDims,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl ModelState {
    pub fn zeros(dims: &ModelDims) -> Self {
        ModelState {
            dims: dims.clone(),
            w1: vec![0.0; dims.features * dims.hidden],
            b1: vec![0.0; dims.hidden],
            w2: vec![0.0; dims.hidden * dims.classes],
            b2: vec![0.0; dims.classes],
        }
    }

    /// Paper §5.1: weights drawn from a normal whose scale depends on the
    /// layer's unit count. We use N(0, 1/sqrt(fan_in)) — the standard,
    /// numerically-sane reading (a literal σ = #units diverges immediately).
    pub fn init(dims: &ModelDims, seed: u64) -> Self {
        let mut m = ModelState::zeros(dims);
        let mut rng = Rng::new(seed);
        let s1 = 1.0 / (dims.features as f64).sqrt();
        for w in &mut m.w1 {
            *w = (rng.normal() * s1) as f32;
        }
        let s2 = 1.0 / (dims.hidden as f64).sqrt();
        for w in &mut m.w2 {
            *w = (rng.normal() * s2) as f32;
        }
        m
    }

    pub fn param_count(&self) -> usize {
        self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len()
    }

    /// The paper's "L2-norm per model parameter" regularization measure
    /// gating merge perturbation (Algorithm 2 line 7), interpreted as the
    /// parameter RMS (`||w||₂ / √N`). A literal `||w||₂ / N` reading makes
    /// the 0.1 default threshold vacuous for any model beyond a few thousand
    /// parameters; RMS preserves the intent — large values flag skewed,
    /// unregularized replicas — at every scale (DESIGN.md notes this).
    pub fn l2_per_param(&self) -> f64 {
        let sq: f64 = self
            .segments()
            .iter()
            .flat_map(|s| s.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum();
        (sq / self.param_count() as f64).sqrt()
    }

    /// Borrow the four parameter segments (merge loops iterate these).
    pub fn segments(&self) -> [&[f32]; 4] {
        [&self.w1, &self.b1, &self.w2, &self.b2]
    }

    pub fn segments_mut(&mut self) -> [&mut [f32]; 4] {
        [&mut self.w1, &mut self.b1, &mut self.w2, &mut self.b2]
    }

    /// `self = sum_i weights[i] * models[i]` (weighted average merge core).
    pub fn set_weighted_sum(&mut self, models: &[&ModelState], weights: &[f64]) {
        assert_eq!(models.len(), weights.len());
        assert!(!models.is_empty());
        for seg in 0..4 {
            let dst_len = self.segments()[seg].len();
            let dst = match seg {
                0 => &mut self.w1,
                1 => &mut self.b1,
                2 => &mut self.w2,
                _ => &mut self.b2,
            };
            debug_assert_eq!(dst.len(), dst_len);
            dst.fill(0.0);
            for (m, &w) in models.iter().zip(weights) {
                let src = m.segments()[seg];
                let wf = w as f32;
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += wf * s;
                }
            }
        }
    }

    /// `self += alpha * (a - b)` — the momentum term of Algorithm 2 line 11.
    pub fn add_scaled_diff(&mut self, a: &ModelState, b: &ModelState, alpha: f64) {
        let af = alpha as f32;
        for seg in 0..4 {
            let dst = match seg {
                0 => &mut self.w1,
                1 => &mut self.b1,
                2 => &mut self.w2,
                _ => &mut self.b2,
            };
            let sa = a.segments()[seg];
            let sb = b.segments()[seg];
            for ((d, &x), &y) in dst.iter_mut().zip(sa).zip(sb) {
                *d += af * (x - y);
            }
        }
    }

    /// Max absolute difference across all parameters (test helper).
    pub fn max_abs_diff(&self, other: &ModelState) -> f32 {
        self.segments()
            .iter()
            .zip(other.segments().iter())
            .flat_map(|(a, b)| a.iter().zip(b.iter()))
            .map(|(&x, &y)| (x - y).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { features: 64, hidden: 8, classes: 16, max_nnz: 8, max_labels: 4 }
    }

    #[test]
    fn init_is_deterministic_and_scaled() {
        let a = ModelState::init(&dims(), 5);
        let b = ModelState::init(&dims(), 5);
        assert_eq!(a, b);
        let c = ModelState::init(&dims(), 6);
        assert!(a.max_abs_diff(&c) > 0.0);
        // Bias starts at zero.
        assert!(a.b1.iter().all(|&x| x == 0.0));
        // Weight scale is sane.
        let rms: f64 = (a.w1.iter().map(|&x| (x as f64).powi(2)).sum::<f64>()
            / a.w1.len() as f64)
            .sqrt();
        assert!((rms - 1.0 / 8.0).abs() < 0.02, "w1 rms {rms}"); // 1/sqrt(64)
    }

    #[test]
    fn weighted_sum_identity_and_average() {
        let d = dims();
        let a = ModelState::init(&d, 1);
        let b = ModelState::init(&d, 2);
        let mut out = ModelState::zeros(&d);
        out.set_weighted_sum(&[&a], &[1.0]);
        assert!(out.max_abs_diff(&a) < 1e-7);
        out.set_weighted_sum(&[&a, &b], &[0.5, 0.5]);
        let expect = 0.5 * a.w1[0] + 0.5 * b.w1[0];
        assert!((out.w1[0] - expect).abs() < 1e-7);
    }

    #[test]
    fn momentum_term_algebra() {
        let d = dims();
        let a = ModelState::init(&d, 3);
        let b = ModelState::init(&d, 4);
        let mut out = ModelState::zeros(&d);
        out.add_scaled_diff(&a, &b, 0.9);
        let expect = 0.9 * (a.w2[7] - b.w2[7]);
        assert!((out.w2[7] - expect).abs() < 1e-7);
    }

    #[test]
    fn l2_per_param_monotone_in_scale() {
        let d = dims();
        let a = ModelState::init(&d, 1);
        let mut big = a.clone();
        for w in &mut big.w1 {
            *w *= 10.0;
        }
        assert!(big.l2_per_param() > a.l2_per_param());
        assert_eq!(ModelState::zeros(&d).l2_per_param(), 0.0);
    }

    #[test]
    fn param_count_matches_dims() {
        let d = dims();
        assert_eq!(ModelState::zeros(&d).param_count(), d.param_count());
    }
}

//! Model checkpointing: save/restore `ModelState` (binary, versioned) so
//! long runs can resume and trained models can be served or inspected.
//!
//! Format (little-endian):
//! ```text
//! magic   b"HSCKPT01"
//! dims    5 × u64   (features, hidden, classes, max_nnz, max_labels)
//! lens    4 × u64   (w1, b1, w2, b2 element counts — redundant, validated)
//! data    4 segments of f32 LE
//! crc     u64       (FNV-1a over the raw data bytes)
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context};

use crate::config::ModelDims;
use crate::Result;

use super::ModelState;

const MAGIC: &[u8; 8] = b"HSCKPT01";

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Save a checkpoint (atomic: write to `.tmp` then rename). A failure at
/// any point after the temp file was created removes it — a bailed save
/// never leaves a stray `.tmp` next to the checkpoint.
pub fn save(model: &ModelState, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    let write_and_rename = || -> Result<()> {
        {
            let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
            w.write_all(MAGIC)?;
            let d = &model.dims;
            for v in [d.features, d.hidden, d.classes, d.max_nnz, d.max_labels] {
                w.write_all(&(v as u64).to_le_bytes())?;
            }
            let segs = model.segments();
            for s in &segs {
                w.write_all(&(s.len() as u64).to_le_bytes())?;
            }
            let mut crc = 0xcbf29ce484222325u64;
            for s in &segs {
                let bytes = f32s_to_bytes(s);
                // Chain the per-segment FNV state through all segments.
                crc ^= fnv1a(&bytes);
                crc = crc.wrapping_mul(0x100000001b3);
                w.write_all(&bytes)?;
            }
            w.write_all(&crc.to_le_bytes())?;
            w.flush()?;
        }
        std::fs::rename(&tmp, path).context("renaming checkpoint into place")
    };
    let result = write_and_rename();
    if result.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    result
}

/// Load and validate a checkpoint.
pub fn load(path: &Path) -> Result<ModelState> {
    let mut r = BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("checkpoint {} is truncated (missing header)", path.display()))?;
    if &magic != MAGIC {
        bail!("{} is not a heterosparse checkpoint (bad magic)", path.display());
    }
    let mut u64buf = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<std::fs::File>| -> Result<u64> {
        r.read_exact(&mut u64buf)?;
        Ok(u64::from_le_bytes(u64buf))
    };
    let dims = ModelDims {
        features: read_u64(&mut r)? as usize,
        hidden: read_u64(&mut r)? as usize,
        classes: read_u64(&mut r)? as usize,
        max_nnz: read_u64(&mut r)? as usize,
        max_labels: read_u64(&mut r)? as usize,
    };
    let lens: Vec<usize> = (0..4).map(|_| read_u64(&mut r).map(|v| v as usize)).collect::<Result<_>>()?;
    let expect = [
        dims.features * dims.hidden,
        dims.hidden,
        dims.hidden * dims.classes,
        dims.classes,
    ];
    if lens != expect {
        bail!("checkpoint segment lengths {lens:?} disagree with dims {dims:?}");
    }
    let mut segs: Vec<Vec<f32>> = Vec::with_capacity(4);
    let mut crc = 0xcbf29ce484222325u64;
    for (seg, &len) in lens.iter().enumerate() {
        let mut bytes = vec![0u8; len * 4];
        r.read_exact(&mut bytes).with_context(|| {
            format!(
                "checkpoint {} is truncated (segment {seg} expected {} bytes)",
                path.display(),
                len * 4
            )
        })?;
        crc ^= fnv1a(&bytes);
        crc = crc.wrapping_mul(0x100000001b3);
        segs.push(bytes_to_f32s(&bytes));
    }
    let stored_crc = read_u64(&mut r)
        .with_context(|| format!("checkpoint {} is truncated (missing crc)", path.display()))?;
    if stored_crc != crc {
        bail!("checkpoint {} is corrupt (crc mismatch)", path.display());
    }
    let b2 = segs.pop().unwrap();
    let w2 = segs.pop().unwrap();
    let b1 = segs.pop().unwrap();
    let w1 = segs.pop().unwrap();
    Ok(ModelState { dims, w1, b1, w2, b2 })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { features: 64, hidden: 8, classes: 16, max_nnz: 8, max_labels: 4 }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hs-ckpt-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip_exact() {
        let m = ModelState::init(&dims(), 9);
        let path = tmp("rt.ckpt");
        save(&m, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn corruption_detected() {
        let m = ModelState::init(&dims(), 10);
        let path = tmp("corrupt.ckpt");
        save(&m, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("crc") || err.contains("corrupt"), "{err}");
    }

    #[test]
    fn wrong_magic_rejected() {
        let path = tmp("magic.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxx").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let m = ModelState::init(&dims(), 11);
        let path = tmp("trunc.ckpt");
        save(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
        assert!(load(&path).is_err());
    }

    /// Property: save → load is the identity over random dims and seeds.
    #[test]
    fn round_trip_property() {
        use crate::util::prop::{self, VecU64};
        // [features, hidden, classes, max_nnz, max_labels, seed]
        let gen = VecU64 { min_len: 6, max_len: 7, item_lo: 1, item_hi: 40 };
        prop::check(25, 17, gen, |v| {
            let d = ModelDims {
                features: v[0] as usize,
                hidden: v[1] as usize,
                classes: v[2] as usize,
                max_nnz: v[3] as usize,
                max_labels: v[4] as usize,
            };
            let m = ModelState::init(&d, v[5]);
            let path = tmp(&format!("prop-{}-{}-{}.ckpt", v[0], v[1], v[5]));
            save(&m, &path).map_err(|e| e.to_string())?;
            let back = load(&path).map_err(|e| e.to_string())?;
            let _ = std::fs::remove_file(&path);
            if back != m {
                return Err("round trip changed the model".to_string());
            }
            Ok(())
        });
    }

    #[test]
    fn flipped_data_byte_reports_crc_mismatch() {
        let m = ModelState::init(&dims(), 21);
        let path = tmp("flip.ckpt");
        save(&m, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one byte inside the data section (past magic + dims + lens).
        let data_start = 8 + 5 * 8 + 4 * 8;
        bytes[data_start + 3] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("crc"), "crc mismatch must be named: {err}");
    }

    #[test]
    fn truncation_points_report_clear_errors() {
        let m = ModelState::init(&dims(), 22);
        let path = tmp("trunc-points.ckpt");
        save(&m, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Mid-header, mid-data, and missing-crc truncations all name the
        // file and say "truncated".
        for cut in [4usize, 8 + 5 * 8 + 4 * 8 + 10, bytes.len() - 4] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let err = format!("{:#}", load(&path).unwrap_err());
            assert!(err.contains("truncated"), "cut at {cut}: {err}");
            assert!(err.contains("trunc-points.ckpt"), "cut at {cut}: {err}");
        }
    }

    #[test]
    fn failed_save_removes_the_stray_tmp_file() {
        let m = ModelState::init(&dims(), 23);
        // The target path is an occupied directory, so the final rename
        // fails after the temp file was fully written.
        let dir = tmp("save-fail-target.ckpt");
        std::fs::create_dir_all(dir.join("occupant")).unwrap();
        let err = save(&m, &dir);
        assert!(err.is_err(), "rename onto a non-empty directory must fail");
        let stray = dir.with_extension("tmp");
        assert!(!stray.exists(), "failed save left {} behind", stray.display());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Pure-Rust reference MLP — the executable twin of `python/compile/model.py`.
//!
//! Three uses:
//! 1. Oracle for the AOT artifacts: integration tests run the same batch
//!    through the PJRT step executable and this code and demand agreement
//!    to f32 tolerance.
//! 2. Compute core for the SLIDE CPU baseline (`slide/`), which reuses the
//!    dense layers with an active-class set — [`sgd_step_active`] is the
//!    batch-level kernel behind the adaptive-sparsity compute lever.
//! 3. Fallback when artifacts are absent (unit tests of the coordinator
//!    run entirely on this path, keeping them hermetic).
//!
//! The math mirrors model.py line by line: sparse gather-SpMM input layer,
//! ReLU hidden, dense output, normalized multi-hot softmax cross-entropy,
//! masked mean over valid samples, manual backprop, sparse W1 scatter update.
//!
//! # Scratch reuse
//!
//! Every step needs six working buffers (`a`, `h`, `logits`, `lse`,
//! `dlogits`, `da`). [`StepScratch`] owns them across steps (the
//! `BufferPool` recycling idea applied to kernel temporaries): callers on
//! the hot path — both execution engines, the serve replay loop — hold one
//! scratch per device/worker and pass it down, so steady-state stepping
//! performs no per-step allocation. [`sgd_step_ref`] keeps the historical
//! allocate-per-call signature by constructing a fresh scratch, and a
//! recycled scratch is bit-identical to a fresh one: every buffer is either
//! fully overwritten or zero-filled before use.
//!
//! # Invariants
//!
//! * `sgd_step_scratch` with any (fresh or reused) scratch computes
//!   bit-identically to the historical `sgd_step_ref`.
//! * `sgd_step_active` with the full class set (`active = 0..classes`)
//!   performs the exact floating-point operations of the dense path in the
//!   same order — bit-identical, not merely close.
//! * `sgd_step_active` never reads or writes `w2`/`b2` entries of classes
//!   outside `active`.

use crate::data::PaddedBatch;

use super::ModelState;

/// Reusable working buffers for [`sgd_step_scratch`] / [`sgd_step_active`]
/// / [`eval_scratch`]. One per device/worker; buffers grow to the largest
/// shape seen and are recycled across steps.
#[derive(Default)]
pub struct StepScratch {
    /// Pre-activation `[b, hidden]`.
    a: Vec<f32>,
    /// ReLU activation `[b, hidden]`.
    h: Vec<f32>,
    /// Output logits `[b, classes]` (dense) or `[b, |active|]` (sparse).
    logits: Vec<f32>,
    /// Per-row log-sum-exp `[b]`.
    lse: Vec<f32>,
    /// Logit gradients, same shape as `logits`.
    dlogits: Vec<f32>,
    /// Activation gradients `[b, hidden]`.
    da: Vec<f32>,
    /// Class id → position in the active set (`u32::MAX` = inactive);
    /// sized `[classes]`, rebuilt per active-set step.
    class_pos: Vec<u32>,
    /// Eval-only row buffers `[hidden]` / `[classes]`.
    arow: Vec<f32>,
    lrow: Vec<f32>,
}

impl StepScratch {
    /// An empty scratch; buffers are sized lazily by the first step.
    pub fn new() -> StepScratch {
        StepScratch::default()
    }

    /// Size (and zero) the hidden-layer buffers. `clear` + `resize`
    /// zero-fills, which is exactly what fresh `vec!` allocation gave the
    /// kernels — recycling cannot change the math.
    fn prepare_hidden(&mut self, b: usize, h_dim: usize) {
        self.a.clear();
        self.a.resize(b * h_dim, 0.0);
        self.h.clear();
        self.h.resize(b * h_dim, 0.0);
        self.da.clear();
        self.da.resize(b * h_dim, 0.0);
    }

    /// Size (and zero) the output-layer buffers for `c_cols` participating
    /// classes (all of them on the dense path, `|active|` on the sparse).
    fn prepare_output(&mut self, b: usize, c_cols: usize) {
        self.logits.clear();
        self.logits.resize(b * c_cols, 0.0);
        self.lse.clear();
        self.lse.resize(b, 0.0);
        self.dlogits.clear();
        self.dlogits.resize(b * c_cols, 0.0);
    }

    /// Row `r` of the ReLU hidden activation — valid after
    /// [`forward_hidden`] until the next step on this scratch. The
    /// sparsity stepper queries LSH tables with these rows, reusing the
    /// forward pass the step itself needs.
    pub fn hidden_row(&self, r: usize, h_dim: usize) -> &[f32] {
        &self.h[r * h_dim..(r + 1) * h_dim]
    }
}

/// Sparse-gather input layer + ReLU into `scratch.a` / `scratch.h` —
/// the (exact, every-hidden-unit) forward shared by the dense path and the
/// active-set path. Sizes the hidden buffers itself.
pub fn forward_hidden(m: &ModelState, batch: &PaddedBatch, scratch: &mut StepScratch) {
    let d = &m.dims;
    let (h_dim, k) = (d.hidden, d.max_nnz);
    let b = batch.bucket;
    scratch.prepare_hidden(b, h_dim);
    for r in 0..b {
        let arow = &mut scratch.a[r * h_dim..(r + 1) * h_dim];
        arow.copy_from_slice(&m.b1);
        for j in 0..k {
            let v = batch.val[r * k + j];
            if v != 0.0 {
                let fi = batch.idx[r * k + j] as usize;
                let wrow = &m.w1[fi * h_dim..(fi + 1) * h_dim];
                for (acc, &w) in arow.iter_mut().zip(wrow) {
                    *acc += v * w;
                }
            }
        }
    }
    for (hv, &av) in scratch.h.iter_mut().zip(&scratch.a) {
        *hv = av.max(0.0);
    }
}

/// Input-layer backward + update (shared tail of both paths): ReLU-gated
/// `da` is already in `scratch.da`; apply `b1 -= lr Σ da` and the sparse
/// `w1` scatter.
fn update_input_layer(m: &mut ModelState, batch: &PaddedBatch, lr: f32, scratch: &StepScratch) {
    let d = &m.dims;
    let (h_dim, k) = (d.hidden, d.max_nnz);
    let b = batch.bucket;
    for r in 0..b {
        let darow = &scratch.da[r * h_dim..(r + 1) * h_dim];
        for (bb, &dv) in m.b1.iter_mut().zip(darow) {
            *bb -= lr * dv;
        }
    }
    for r in 0..b {
        let darow = &scratch.da[r * h_dim..(r + 1) * h_dim];
        for j in 0..k {
            let v = batch.val[r * k + j];
            if v != 0.0 {
                let fi = batch.idx[r * k + j] as usize;
                let wrow = &mut m.w1[fi * h_dim..(fi + 1) * h_dim];
                let s = lr * v;
                for (w, &dv) in wrow.iter_mut().zip(darow) {
                    *w -= s * dv;
                }
            }
        }
    }
}

/// Forward + backward + in-place SGD update. Returns the batch loss.
///
/// Allocates a fresh scratch per call (the historical contract); hot paths
/// should hold a [`StepScratch`] and call [`sgd_step_scratch`] instead.
pub fn sgd_step_ref(m: &mut ModelState, batch: &PaddedBatch, lr: f32) -> f32 {
    sgd_step_scratch(m, batch, lr, &mut StepScratch::new())
}

/// [`sgd_step_ref`] with caller-owned working buffers — bit-identical
/// output, no per-step allocation once the scratch has warmed up.
pub fn sgd_step_scratch(
    m: &mut ModelState,
    batch: &PaddedBatch,
    lr: f32,
    scratch: &mut StepScratch,
) -> f32 {
    let d = &m.dims;
    let (h_dim, c_dim, l) = (d.hidden, d.classes, d.max_labels);
    let b = batch.bucket;

    // ---- forward ----------------------------------------------------------
    // a = sparse_embed(idx, val, w1) + b1 ; h = relu(a)
    forward_hidden(m, batch, scratch);
    scratch.prepare_output(b, c_dim);

    // logits = h @ w2 + b2
    for r in 0..b {
        let lrow = &mut scratch.logits[r * c_dim..(r + 1) * c_dim];
        lrow.copy_from_slice(&m.b2);
        let hrow = &scratch.h[r * h_dim..(r + 1) * h_dim];
        for (hi, &hv) in hrow.iter().enumerate() {
            if hv != 0.0 {
                let wrow = &m.w2[hi * c_dim..(hi + 1) * c_dim];
                for (lo, &w) in lrow.iter_mut().zip(wrow) {
                    *lo += hv * w;
                }
            }
        }
    }

    // loss_i = logsumexp(logits_i) - sum_l lab_w * logits[lab]
    let valid: f32 = batch.smask.iter().sum::<f32>().max(1.0);
    let mut loss = 0.0f64;
    for r in 0..b {
        let lrow = &scratch.logits[r * c_dim..(r + 1) * c_dim];
        let mx = lrow.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = lrow.iter().map(|&x| (x - mx).exp()).sum();
        scratch.lse[r] = mx + sum.ln();
        let mut pos = 0.0f32;
        for j in 0..l {
            let w = batch.lab_w[r * l + j];
            if w != 0.0 {
                pos += w * lrow[batch.lab[r * l + j] as usize];
            }
        }
        loss += (batch.smask[r] * (scratch.lse[r] - pos)) as f64;
    }
    let loss = (loss / valid as f64) as f32;

    // ---- backward ---------------------------------------------------------
    // dlogits = (softmax - y) * smask / n
    for r in 0..b {
        let scale = batch.smask[r] / valid;
        if scale == 0.0 {
            continue;
        }
        let lrow = &scratch.logits[r * c_dim..(r + 1) * c_dim];
        let drow = &mut scratch.dlogits[r * c_dim..(r + 1) * c_dim];
        for (dl, &lo) in drow.iter_mut().zip(lrow) {
            *dl = (lo - scratch.lse[r]).exp() * scale;
        }
        for j in 0..l {
            let w = batch.lab_w[r * l + j];
            if w != 0.0 {
                drow[batch.lab[r * l + j] as usize] -= w * scale;
            }
        }
    }

    // dh = dlogits @ w2^T ; dw2 = h^T @ dlogits ; db2 = sum dlogits
    // da = dh * (a > 0) ; db1 = sum da
    for r in 0..b {
        let drow = &scratch.dlogits[r * c_dim..(r + 1) * c_dim];
        let darow = &mut scratch.da[r * h_dim..(r + 1) * h_dim];
        for hi in 0..h_dim {
            if scratch.a[r * h_dim + hi] > 0.0 {
                let wrow = &m.w2[hi * c_dim..(hi + 1) * c_dim];
                let mut acc = 0.0f32;
                for (&dl, &w) in drow.iter().zip(wrow) {
                    acc += dl * w;
                }
                darow[hi] = acc;
            }
        }
    }

    // ---- updates (order matters: read h/da before mutating weights) ------
    // w2 -= lr * h^T dlogits ; b2 -= lr * sum dlogits
    for r in 0..b {
        let hrow = &scratch.h[r * h_dim..(r + 1) * h_dim];
        let drow = &scratch.dlogits[r * c_dim..(r + 1) * c_dim];
        for (hi, &hv) in hrow.iter().enumerate() {
            if hv != 0.0 {
                let wrow = &mut m.w2[hi * c_dim..(hi + 1) * c_dim];
                let s = lr * hv;
                for (w, &dl) in wrow.iter_mut().zip(drow) {
                    *w -= s * dl;
                }
            }
        }
    }
    for r in 0..b {
        let drow = &scratch.dlogits[r * c_dim..(r + 1) * c_dim];
        for (bb, &dl) in m.b2.iter_mut().zip(drow) {
            *bb -= lr * dl;
        }
    }

    // b1 -= lr * sum da ; w1[idx] -= lr * val * da  (sparse scatter)
    update_input_layer(m, batch, lr, scratch);

    loss
}

/// One batch-level **active-class** SGD step: the softmax, loss, and
/// output-layer backward/update are restricted to the classes in `active`
/// (SLIDE's trick, lifted from the per-sample Hogwild path in
/// `slide/network.rs` onto a plain [`ModelState`] so the execution engines
/// can schedule it). The input layer stays exact.
///
/// `active` must be sorted ascending, deduplicated, and contain every
/// class that appears with nonzero label weight in the batch (labels must
/// participate in their own softmax). Returns the batch loss over the
/// restricted softmax.
///
/// With `active` = all classes this performs the dense path's exact
/// floating-point operations in the same order — bit-identical to
/// [`sgd_step_scratch`] — and classes outside `active` have their `w2`
/// columns and `b2` entries neither read nor written.
pub fn sgd_step_active(
    m: &mut ModelState,
    batch: &PaddedBatch,
    lr: f32,
    active: &[u32],
    scratch: &mut StepScratch,
) -> f32 {
    forward_hidden(m, batch, scratch);
    sgd_step_active_prepared(m, batch, lr, active, scratch)
}

/// [`sgd_step_active`] continuing from a forward pass already in
/// `scratch` (via [`forward_hidden`] on the same `m`/`batch`) — the
/// sparsity stepper runs the forward once, queries its LSH tables with the
/// hidden rows, then finishes the step here without recomputing them.
pub fn sgd_step_active_prepared(
    m: &mut ModelState,
    batch: &PaddedBatch,
    lr: f32,
    active: &[u32],
    scratch: &mut StepScratch,
) -> f32 {
    let d = &m.dims;
    let (h_dim, c_dim, l) = (d.hidden, d.classes, d.max_labels);
    let b = batch.bucket;
    let n_act = active.len();
    debug_assert!(n_act > 0, "active set must be non-empty");
    debug_assert!(active.windows(2).all(|w| w[0] < w[1]), "active must be sorted + deduped");
    debug_assert!(active.last().map(|&c| (c as usize) < c_dim).unwrap_or(true));
    debug_assert_eq!(scratch.h.len(), b * h_dim, "forward_hidden must run first");
    scratch.prepare_output(b, n_act);

    // class id -> active position (u32::MAX = inactive).
    scratch.class_pos.clear();
    scratch.class_pos.resize(c_dim, u32::MAX);
    for (j, &c) in active.iter().enumerate() {
        scratch.class_pos[c as usize] = j as u32;
    }

    // logits[:, j] = h @ w2[:, active[j]] + b2[active[j]]
    for r in 0..b {
        let lrow = &mut scratch.logits[r * n_act..(r + 1) * n_act];
        for (lo, &c) in lrow.iter_mut().zip(active) {
            *lo = m.b2[c as usize];
        }
        let hrow = &scratch.h[r * h_dim..(r + 1) * h_dim];
        for (hi, &hv) in hrow.iter().enumerate() {
            if hv != 0.0 {
                let wrow = &m.w2[hi * c_dim..(hi + 1) * c_dim];
                for (lo, &c) in lrow.iter_mut().zip(active) {
                    *lo += hv * wrow[c as usize];
                }
            }
        }
    }

    // Restricted-softmax loss over the active set.
    let valid: f32 = batch.smask.iter().sum::<f32>().max(1.0);
    let mut loss = 0.0f64;
    for r in 0..b {
        let lrow = &scratch.logits[r * n_act..(r + 1) * n_act];
        let mx = lrow.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = lrow.iter().map(|&x| (x - mx).exp()).sum();
        scratch.lse[r] = mx + sum.ln();
        let mut pos = 0.0f32;
        for j in 0..l {
            let w = batch.lab_w[r * l + j];
            if w != 0.0 {
                let p = scratch.class_pos[batch.lab[r * l + j] as usize];
                debug_assert!(p != u32::MAX, "label class missing from the active set");
                pos += w * lrow[p as usize];
            }
        }
        loss += (batch.smask[r] * (scratch.lse[r] - pos)) as f64;
    }
    let loss = (loss / valid as f64) as f32;

    // ---- backward over active classes -------------------------------------
    for r in 0..b {
        let scale = batch.smask[r] / valid;
        if scale == 0.0 {
            continue;
        }
        let lrow = &scratch.logits[r * n_act..(r + 1) * n_act];
        let drow = &mut scratch.dlogits[r * n_act..(r + 1) * n_act];
        for (dl, &lo) in drow.iter_mut().zip(lrow) {
            *dl = (lo - scratch.lse[r]).exp() * scale;
        }
        for j in 0..l {
            let w = batch.lab_w[r * l + j];
            if w != 0.0 {
                let p = scratch.class_pos[batch.lab[r * l + j] as usize];
                drow[p as usize] -= w * scale;
            }
        }
    }

    for r in 0..b {
        let drow = &scratch.dlogits[r * n_act..(r + 1) * n_act];
        let darow = &mut scratch.da[r * h_dim..(r + 1) * h_dim];
        for hi in 0..h_dim {
            if scratch.a[r * h_dim + hi] > 0.0 {
                let wrow = &m.w2[hi * c_dim..(hi + 1) * c_dim];
                let mut acc = 0.0f32;
                for (&dl, &c) in drow.iter().zip(active) {
                    acc += dl * wrow[c as usize];
                }
                darow[hi] = acc;
            }
        }
    }

    // ---- updates: active columns of w2/b2, then the exact input layer ----
    for r in 0..b {
        let hrow = &scratch.h[r * h_dim..(r + 1) * h_dim];
        let drow = &scratch.dlogits[r * n_act..(r + 1) * n_act];
        for (hi, &hv) in hrow.iter().enumerate() {
            if hv != 0.0 {
                let wrow = &mut m.w2[hi * c_dim..(hi + 1) * c_dim];
                let s = lr * hv;
                for (&dl, &c) in drow.iter().zip(active) {
                    wrow[c as usize] -= s * dl;
                }
            }
        }
    }
    for r in 0..b {
        let drow = &scratch.dlogits[r * n_act..(r + 1) * n_act];
        for (&dl, &c) in drow.iter().zip(active) {
            m.b2[c as usize] -= lr * dl;
        }
    }

    update_input_layer(m, batch, lr, scratch);

    loss
}

/// Forward-only top-1 prediction (mirrors model.py `eval_batch`).
/// Allocates its row buffers per call; hot paths should use
/// [`eval_scratch`].
pub fn eval_ref(m: &ModelState, batch: &PaddedBatch) -> Vec<i32> {
    eval_scratch(m, batch, &mut StepScratch::new())
}

/// [`eval_ref`] with caller-owned row buffers — identical predictions, no
/// per-call allocation beyond the returned vector.
pub fn eval_scratch(m: &ModelState, batch: &PaddedBatch, scratch: &mut StepScratch) -> Vec<i32> {
    let d = &m.dims;
    let (h_dim, c_dim, k) = (d.hidden, d.classes, d.max_nnz);
    let b = batch.bucket;
    let mut preds = vec![0i32; b];
    scratch.arow.clear();
    scratch.arow.resize(h_dim, 0.0);
    scratch.lrow.clear();
    scratch.lrow.resize(c_dim, 0.0);
    let (arow, lrow) = (&mut scratch.arow, &mut scratch.lrow);
    for r in 0..b {
        arow.copy_from_slice(&m.b1);
        for j in 0..k {
            let v = batch.val[r * k + j];
            if v != 0.0 {
                let fi = batch.idx[r * k + j] as usize;
                let wrow = &m.w1[fi * h_dim..(fi + 1) * h_dim];
                for (acc, &w) in arow.iter_mut().zip(wrow) {
                    *acc += v * w;
                }
            }
        }
        lrow.copy_from_slice(&m.b2);
        for (hi, &av) in arow.iter().enumerate() {
            let hv = av.max(0.0);
            if hv != 0.0 {
                let wrow = &m.w2[hi * c_dim..(hi + 1) * c_dim];
                for (lo, &w) in lrow.iter_mut().zip(wrow) {
                    *lo += hv * w;
                }
            }
        }
        // Argmax with lowest-index tie-break (matches jnp.argmax).
        let mut best = 0usize;
        for (c, &v) in lrow.iter().enumerate() {
            if v > lrow[best] {
                best = c;
            }
        }
        preds[r] = best as i32;
    }
    preds
}

/// Approximate forward-only top-1 restricted to `active` (sorted class
/// ids): the serving plane's cheap inference mode — only the active
/// columns of the output layer are read. Predictions are the argmax over
/// the active set (lowest class id on ties).
pub fn eval_active(
    m: &ModelState,
    batch: &PaddedBatch,
    active: &[u32],
    scratch: &mut StepScratch,
) -> Vec<i32> {
    let d = &m.dims;
    let (h_dim, c_dim, kk) = (d.hidden, d.classes, d.max_nnz);
    let b = batch.bucket;
    debug_assert!(!active.is_empty());
    let mut preds = vec![0i32; b];
    scratch.arow.clear();
    scratch.arow.resize(h_dim, 0.0);
    scratch.lrow.clear();
    scratch.lrow.resize(active.len(), 0.0);
    let (arow, lrow) = (&mut scratch.arow, &mut scratch.lrow);
    for r in 0..b {
        arow.copy_from_slice(&m.b1);
        for j in 0..kk {
            let v = batch.val[r * kk + j];
            if v != 0.0 {
                let fi = batch.idx[r * kk + j] as usize;
                let wrow = &m.w1[fi * h_dim..(fi + 1) * h_dim];
                for (acc, &w) in arow.iter_mut().zip(wrow) {
                    *acc += v * w;
                }
            }
        }
        for (lo, &c) in lrow.iter_mut().zip(active) {
            *lo = m.b2[c as usize];
        }
        for (hi, &av) in arow.iter().enumerate() {
            let hv = av.max(0.0);
            if hv != 0.0 {
                let wrow = &m.w2[hi * c_dim..(hi + 1) * c_dim];
                for (lo, &c) in lrow.iter_mut().zip(active) {
                    *lo += hv * wrow[c as usize];
                }
            }
        }
        let mut best = 0usize;
        for (j, &v) in lrow.iter().enumerate() {
            if v > lrow[best] {
                best = j;
            }
        }
        preds[r] = active[best] as i32;
    }
    preds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::data::batcher::Batcher;
    use crate::data::synthetic::Generator;

    fn setup() -> (ModelDims, crate::data::SparseDataset) {
        let dims = ModelDims { features: 128, hidden: 16, classes: 32, max_nnz: 12, max_labels: 4 };
        let cfg = DataConfig { train_samples: 200, avg_nnz: 6.0, ..Default::default() };
        let ds = Generator::new(&dims, &cfg).generate(200, 1);
        (dims, ds)
    }

    #[test]
    fn loss_decreases_under_training() {
        let (dims, ds) = setup();
        let mut m = ModelState::init(&dims, 1);
        let mut batcher = Batcher::new(&ds, &dims, 2);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..60 {
            let b = batcher.next_batch(32, 32);
            last = sgd_step_ref(&mut m, &b, 0.1);
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap(), "loss {} -> {last}", first.unwrap());
    }

    #[test]
    fn masked_rows_do_not_change_update() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 3);
        let full = batcher.next_batch(16, 10);
        // Build the unpadded twin: same 10 samples in a 10-bucket.
        let mut tight = full.clone();
        tight.bucket = 10;
        tight.idx.truncate(10 * dims.max_nnz);
        tight.val.truncate(10 * dims.max_nnz);
        tight.lab.truncate(10 * dims.max_labels);
        tight.lab_w.truncate(10 * dims.max_labels);
        tight.smask.truncate(10);

        let mut m1 = ModelState::init(&dims, 9);
        let mut m2 = m1.clone();
        let l1 = sgd_step_ref(&mut m1, &full, 0.05);
        let l2 = sgd_step_ref(&mut m2, &tight, 0.05);
        assert!((l1 - l2).abs() < 1e-6);
        assert!(m1.max_abs_diff(&m2) < 1e-6);
    }

    #[test]
    fn recycled_scratch_is_bit_identical_to_fresh() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 13);
        // Warm the scratch on a different (larger) shape first so reuse
        // actually exercises the resize-and-zero path.
        let warm = batcher.next_batch(32, 32);
        let b1 = batcher.next_batch(16, 16);
        let b2 = batcher.next_batch(16, 16);

        let mut scratch = StepScratch::new();
        let mut warm_model = ModelState::init(&dims, 3);
        sgd_step_scratch(&mut warm_model, &warm, 0.1, &mut scratch);

        let mut fresh_m = ModelState::init(&dims, 4);
        let mut pooled_m = fresh_m.clone();
        for batch in [&b1, &b2] {
            let lf = sgd_step_ref(&mut fresh_m, batch, 0.07);
            let lp = sgd_step_scratch(&mut pooled_m, batch, 0.07, &mut scratch);
            assert_eq!(lf.to_bits(), lp.to_bits(), "loss must be bit-identical");
        }
        assert_eq!(fresh_m, pooled_m, "recycled scratch changed the step");
        // Eval path too.
        let ef = eval_ref(&fresh_m, &b1);
        let ep = eval_scratch(&pooled_m, &b1, &mut scratch);
        assert_eq!(ef, ep);
    }

    #[test]
    fn full_active_set_is_bit_identical_to_dense() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 17);
        let batch = batcher.next_batch(16, 16);
        let all: Vec<u32> = (0..dims.classes as u32).collect();

        let mut dense = ModelState::init(&dims, 8);
        let mut sparse = dense.clone();
        let mut scratch = StepScratch::new();
        let ld = sgd_step_ref(&mut dense, &batch, 0.05);
        let ls = sgd_step_active(&mut sparse, &batch, 0.05, &all, &mut scratch);
        assert_eq!(ld.to_bits(), ls.to_bits(), "loss bits {ld} vs {ls}");
        assert_eq!(dense, sparse, "ratio=1.0 must reproduce the dense path exactly");
    }

    #[test]
    fn inactive_classes_are_never_touched() {
        // Property: for random active subsets (labels always included),
        // w2 columns and b2 entries outside the active set keep their
        // exact bits, while active-class parameters move.
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 23);
        let mut rng = crate::util::rng::Rng::new(77);
        for trial in 0..10 {
            let batch = batcher.next_batch(8, 8);
            // Labels present in the batch must participate.
            let mut active: Vec<u32> = Vec::new();
            for r in 0..batch.bucket {
                for j in 0..dims.max_labels {
                    if batch.lab_w[r * dims.max_labels + j] != 0.0 {
                        active.push(batch.lab[r * dims.max_labels + j]);
                    }
                }
            }
            // Plus a random handful of extra classes.
            for _ in 0..rng.range(1, 8) {
                active.push(rng.range(0, dims.classes) as u32);
            }
            active.sort_unstable();
            active.dedup();

            let before = ModelState::init(&dims, 100 + trial);
            let mut after = before.clone();
            let mut scratch = StepScratch::new();
            sgd_step_active(&mut after, &batch, 0.1, &active, &mut scratch);

            let is_active = |c: usize| active.binary_search(&(c as u32)).is_ok();
            let mut active_moved = false;
            for c in 0..dims.classes {
                let b2_same = before.b2[c].to_bits() == after.b2[c].to_bits();
                let col_same = (0..dims.hidden).all(|hi| {
                    let i = hi * dims.classes + c;
                    before.w2[i].to_bits() == after.w2[i].to_bits()
                });
                if is_active(c) {
                    active_moved |= !b2_same || !col_same;
                } else {
                    assert!(b2_same && col_same, "trial {trial}: inactive class {c} was touched");
                }
            }
            assert!(active_moved, "trial {trial}: the active set must actually train");
        }
    }

    #[test]
    fn eval_active_full_set_matches_dense_eval() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 29);
        let batch = batcher.next_batch(16, 16);
        let m = ModelState::init(&dims, 31);
        let all: Vec<u32> = (0..dims.classes as u32).collect();
        let mut scratch = StepScratch::new();
        assert_eq!(eval_ref(&m, &batch), eval_active(&m, &batch, &all, &mut scratch));
        // A restricted set still predicts within that set.
        let subset: Vec<u32> = (0..dims.classes as u32).step_by(3).collect();
        let preds = eval_active(&m, &batch, &subset, &mut scratch);
        assert!(preds.iter().all(|&p| subset.contains(&(p as u32))));
    }

    #[test]
    fn gradient_check_numerical() {
        // Central-difference check of dloss/dw for a few random parameters.
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 5);
        let batch = batcher.next_batch(8, 8);
        let m0 = ModelState::init(&dims, 11);

        let loss_of = |m: &ModelState| {
            let mut mm = m.clone();
            // lr=0 step computes the loss without mutating.
            sgd_step_ref(&mut mm, &batch, 0.0)
        };

        // Analytic gradient via a tiny-lr step: g ≈ (w - w') / lr.
        let lr = 1e-3f32;
        let mut m1 = m0.clone();
        sgd_step_ref(&mut m1, &batch, lr);

        let eps = 3e-3f32;
        // Probe a touched w1 row, a w2 entry, and biases.
        let probe_w1 = (batch.idx[0] as usize) * dims.hidden;
        for &(seg, idx) in &[(0usize, probe_w1), (2usize, 5), (1usize, 0), (3usize, 7)] {
            let analytic = {
                let (orig, new): (f32, f32) = match seg {
                    0 => (m0.w1[idx], m1.w1[idx]),
                    1 => (m0.b1[idx], m1.b1[idx]),
                    2 => (m0.w2[idx], m1.w2[idx]),
                    _ => (m0.b2[idx], m1.b2[idx]),
                };
                (orig - new) / lr
            };
            let numeric = {
                let mut mp = m0.clone();
                let mut mm = m0.clone();
                match seg {
                    0 => {
                        mp.w1[idx] += eps;
                        mm.w1[idx] -= eps;
                    }
                    1 => {
                        mp.b1[idx] += eps;
                        mm.b1[idx] -= eps;
                    }
                    2 => {
                        mp.w2[idx] += eps;
                        mm.w2[idx] -= eps;
                    }
                    _ => {
                        mp.b2[idx] += eps;
                        mm.b2[idx] -= eps;
                    }
                }
                (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps)
            };
            let denom = analytic.abs().max(numeric.abs()).max(1e-4);
            assert!(
                (analytic - numeric).abs() / denom < 0.08,
                "seg {seg} idx {idx}: analytic {analytic} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn eval_improves_with_training() {
        let (dims, ds) = setup();
        let test = Generator::new(
            &dims,
            &DataConfig { train_samples: 200, avg_nnz: 6.0, ..Default::default() },
        )
        .generate(150, 2);
        let mut m = ModelState::init(&dims, 21);
        let eb = crate::data::batcher::EvalBatches::new(&test, &dims, 64);
        let p_at_1 = |m: &ModelState| {
            let mut hit = 0usize;
            let mut total = 0usize;
            for batch in &eb.batches {
                let preds = eval_ref(m, batch);
                for (r, &id) in batch.sample_ids.iter().enumerate() {
                    total += 1;
                    if test.sample(id as usize).labels.contains(&(preds[r] as u32)) {
                        hit += 1;
                    }
                }
            }
            hit as f64 / total as f64
        };
        let before = p_at_1(&m);
        let mut batcher = Batcher::new(&ds, &dims, 7);
        for _ in 0..150 {
            let b = batcher.next_batch(32, 32);
            sgd_step_ref(&mut m, &b, 0.2);
        }
        let after = p_at_1(&m);
        assert!(after > before + 0.05, "P@1 {before} -> {after}");
    }
}

//! Pure-Rust reference MLP — the executable twin of `python/compile/model.py`.
//!
//! Three uses:
//! 1. Oracle for the AOT artifacts: integration tests run the same batch
//!    through the PJRT step executable and this code and demand agreement
//!    to f32 tolerance.
//! 2. Compute core for the SLIDE CPU baseline (`slide/`), which reuses the
//!    dense layers with an active-class set.
//! 3. Fallback when artifacts are absent (unit tests of the coordinator
//!    run entirely on this path, keeping them hermetic).
//!
//! The math mirrors model.py line by line: sparse gather-SpMM input layer,
//! ReLU hidden, dense output, normalized multi-hot softmax cross-entropy,
//! masked mean over valid samples, manual backprop, sparse W1 scatter update.

use crate::data::PaddedBatch;

use super::ModelState;

/// Forward + backward + in-place SGD update. Returns the batch loss.
pub fn sgd_step_ref(m: &mut ModelState, batch: &PaddedBatch, lr: f32) -> f32 {
    let d = &m.dims;
    let (h_dim, c_dim, k, l) = (d.hidden, d.classes, d.max_nnz, d.max_labels);
    let b = batch.bucket;

    // ---- forward ----------------------------------------------------------
    // a = sparse_embed(idx, val, w1) + b1 ; h = relu(a)
    let mut a = vec![0.0f32; b * h_dim];
    for r in 0..b {
        let arow = &mut a[r * h_dim..(r + 1) * h_dim];
        arow.copy_from_slice(&m.b1);
        for j in 0..k {
            let v = batch.val[r * k + j];
            if v != 0.0 {
                let fi = batch.idx[r * k + j] as usize;
                let wrow = &m.w1[fi * h_dim..(fi + 1) * h_dim];
                for (acc, &w) in arow.iter_mut().zip(wrow) {
                    *acc += v * w;
                }
            }
        }
    }
    let h: Vec<f32> = a.iter().map(|&x| x.max(0.0)).collect();

    // logits = h @ w2 + b2
    let mut logits = vec![0.0f32; b * c_dim];
    for r in 0..b {
        let lrow = &mut logits[r * c_dim..(r + 1) * c_dim];
        lrow.copy_from_slice(&m.b2);
        let hrow = &h[r * h_dim..(r + 1) * h_dim];
        for (hi, &hv) in hrow.iter().enumerate() {
            if hv != 0.0 {
                let wrow = &m.w2[hi * c_dim..(hi + 1) * c_dim];
                for (lo, &w) in lrow.iter_mut().zip(wrow) {
                    *lo += hv * w;
                }
            }
        }
    }

    // loss_i = logsumexp(logits_i) - sum_l lab_w * logits[lab]
    let valid: f32 = batch.smask.iter().sum::<f32>().max(1.0);
    let mut lse = vec![0.0f32; b];
    let mut loss = 0.0f64;
    for r in 0..b {
        let lrow = &logits[r * c_dim..(r + 1) * c_dim];
        let mx = lrow.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = lrow.iter().map(|&x| (x - mx).exp()).sum();
        lse[r] = mx + sum.ln();
        let mut pos = 0.0f32;
        for j in 0..l {
            let w = batch.lab_w[r * l + j];
            if w != 0.0 {
                pos += w * lrow[batch.lab[r * l + j] as usize];
            }
        }
        loss += (batch.smask[r] * (lse[r] - pos)) as f64;
    }
    let loss = (loss / valid as f64) as f32;

    // ---- backward ---------------------------------------------------------
    // dlogits = (softmax - y) * smask / n
    let mut dlogits = vec![0.0f32; b * c_dim];
    for r in 0..b {
        let scale = batch.smask[r] / valid;
        if scale == 0.0 {
            continue;
        }
        let lrow = &logits[r * c_dim..(r + 1) * c_dim];
        let drow = &mut dlogits[r * c_dim..(r + 1) * c_dim];
        for (dl, &lo) in drow.iter_mut().zip(lrow) {
            *dl = (lo - lse[r]).exp() * scale;
        }
        for j in 0..l {
            let w = batch.lab_w[r * l + j];
            if w != 0.0 {
                drow[batch.lab[r * l + j] as usize] -= w * scale;
            }
        }
    }

    // dh = dlogits @ w2^T ; dw2 = h^T @ dlogits ; db2 = sum dlogits
    // da = dh * (a > 0) ; db1 = sum da
    let mut da = vec![0.0f32; b * h_dim];
    for r in 0..b {
        let drow = &dlogits[r * c_dim..(r + 1) * c_dim];
        let darow = &mut da[r * h_dim..(r + 1) * h_dim];
        for hi in 0..h_dim {
            if a[r * h_dim + hi] > 0.0 {
                let wrow = &m.w2[hi * c_dim..(hi + 1) * c_dim];
                let mut acc = 0.0f32;
                for (&dl, &w) in drow.iter().zip(wrow) {
                    acc += dl * w;
                }
                darow[hi] = acc;
            }
        }
    }

    // ---- updates (order matters: read h/da before mutating weights) ------
    // w2 -= lr * h^T dlogits ; b2 -= lr * sum dlogits
    for r in 0..b {
        let hrow = &h[r * h_dim..(r + 1) * h_dim];
        let drow = &dlogits[r * c_dim..(r + 1) * c_dim];
        for (hi, &hv) in hrow.iter().enumerate() {
            if hv != 0.0 {
                let wrow = &mut m.w2[hi * c_dim..(hi + 1) * c_dim];
                let s = lr * hv;
                for (w, &dl) in wrow.iter_mut().zip(drow) {
                    *w -= s * dl;
                }
            }
        }
    }
    for r in 0..b {
        let drow = &dlogits[r * c_dim..(r + 1) * c_dim];
        for (bb, &dl) in m.b2.iter_mut().zip(drow) {
            *bb -= lr * dl;
        }
    }

    // b1 -= lr * sum da ; w1[idx] -= lr * val * da  (sparse scatter)
    for r in 0..b {
        let darow = &da[r * h_dim..(r + 1) * h_dim];
        for (bb, &dv) in m.b1.iter_mut().zip(darow) {
            *bb -= lr * dv;
        }
    }
    for r in 0..b {
        let darow = &da[r * h_dim..(r + 1) * h_dim];
        for j in 0..k {
            let v = batch.val[r * k + j];
            if v != 0.0 {
                let fi = batch.idx[r * k + j] as usize;
                let wrow = &mut m.w1[fi * h_dim..(fi + 1) * h_dim];
                let s = lr * v;
                for (w, &dv) in wrow.iter_mut().zip(darow) {
                    *w -= s * dv;
                }
            }
        }
    }

    loss
}

/// Forward-only top-1 prediction (mirrors model.py `eval_batch`).
pub fn eval_ref(m: &ModelState, batch: &PaddedBatch) -> Vec<i32> {
    let d = &m.dims;
    let (h_dim, c_dim, k) = (d.hidden, d.classes, d.max_nnz);
    let b = batch.bucket;
    let mut preds = vec![0i32; b];
    let mut arow = vec![0.0f32; h_dim];
    let mut lrow = vec![0.0f32; c_dim];
    for r in 0..b {
        arow.copy_from_slice(&m.b1);
        for j in 0..k {
            let v = batch.val[r * k + j];
            if v != 0.0 {
                let fi = batch.idx[r * k + j] as usize;
                let wrow = &m.w1[fi * h_dim..(fi + 1) * h_dim];
                for (acc, &w) in arow.iter_mut().zip(wrow) {
                    *acc += v * w;
                }
            }
        }
        lrow.copy_from_slice(&m.b2);
        for (hi, &av) in arow.iter().enumerate() {
            let hv = av.max(0.0);
            if hv != 0.0 {
                let wrow = &m.w2[hi * c_dim..(hi + 1) * c_dim];
                for (lo, &w) in lrow.iter_mut().zip(wrow) {
                    *lo += hv * w;
                }
            }
        }
        // Argmax with lowest-index tie-break (matches jnp.argmax).
        let mut best = 0usize;
        for (c, &v) in lrow.iter().enumerate() {
            if v > lrow[best] {
                best = c;
            }
        }
        preds[r] = best as i32;
    }
    preds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::data::batcher::Batcher;
    use crate::data::synthetic::Generator;

    fn setup() -> (ModelDims, crate::data::SparseDataset) {
        let dims = ModelDims { features: 128, hidden: 16, classes: 32, max_nnz: 12, max_labels: 4 };
        let cfg = DataConfig { train_samples: 200, avg_nnz: 6.0, ..Default::default() };
        let ds = Generator::new(&dims, &cfg).generate(200, 1);
        (dims, ds)
    }

    #[test]
    fn loss_decreases_under_training() {
        let (dims, ds) = setup();
        let mut m = ModelState::init(&dims, 1);
        let mut batcher = Batcher::new(&ds, &dims, 2);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..60 {
            let b = batcher.next_batch(32, 32);
            last = sgd_step_ref(&mut m, &b, 0.1);
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap(), "loss {} -> {last}", first.unwrap());
    }

    #[test]
    fn masked_rows_do_not_change_update() {
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 3);
        let full = batcher.next_batch(16, 10);
        // Build the unpadded twin: same 10 samples in a 10-bucket.
        let mut tight = full.clone();
        tight.bucket = 10;
        tight.idx.truncate(10 * dims.max_nnz);
        tight.val.truncate(10 * dims.max_nnz);
        tight.lab.truncate(10 * dims.max_labels);
        tight.lab_w.truncate(10 * dims.max_labels);
        tight.smask.truncate(10);

        let mut m1 = ModelState::init(&dims, 9);
        let mut m2 = m1.clone();
        let l1 = sgd_step_ref(&mut m1, &full, 0.05);
        let l2 = sgd_step_ref(&mut m2, &tight, 0.05);
        assert!((l1 - l2).abs() < 1e-6);
        assert!(m1.max_abs_diff(&m2) < 1e-6);
    }

    #[test]
    fn gradient_check_numerical() {
        // Central-difference check of dloss/dw for a few random parameters.
        let (dims, ds) = setup();
        let mut batcher = Batcher::new(&ds, &dims, 5);
        let batch = batcher.next_batch(8, 8);
        let m0 = ModelState::init(&dims, 11);

        let loss_of = |m: &ModelState| {
            let mut mm = m.clone();
            // lr=0 step computes the loss without mutating.
            sgd_step_ref(&mut mm, &batch, 0.0)
        };

        // Analytic gradient via a tiny-lr step: g ≈ (w - w') / lr.
        let lr = 1e-3f32;
        let mut m1 = m0.clone();
        sgd_step_ref(&mut m1, &batch, lr);

        let eps = 3e-3f32;
        // Probe a touched w1 row, a w2 entry, and biases.
        let probe_w1 = (batch.idx[0] as usize) * dims.hidden;
        for &(seg, idx) in &[(0usize, probe_w1), (2usize, 5), (1usize, 0), (3usize, 7)] {
            let analytic = {
                let (orig, new): (f32, f32) = match seg {
                    0 => (m0.w1[idx], m1.w1[idx]),
                    1 => (m0.b1[idx], m1.b1[idx]),
                    2 => (m0.w2[idx], m1.w2[idx]),
                    _ => (m0.b2[idx], m1.b2[idx]),
                };
                (orig - new) / lr
            };
            let numeric = {
                let mut mp = m0.clone();
                let mut mm = m0.clone();
                match seg {
                    0 => {
                        mp.w1[idx] += eps;
                        mm.w1[idx] -= eps;
                    }
                    1 => {
                        mp.b1[idx] += eps;
                        mm.b1[idx] -= eps;
                    }
                    2 => {
                        mp.w2[idx] += eps;
                        mm.w2[idx] -= eps;
                    }
                    _ => {
                        mp.b2[idx] += eps;
                        mm.b2[idx] -= eps;
                    }
                }
                (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps)
            };
            let denom = analytic.abs().max(numeric.abs()).max(1e-4);
            assert!(
                (analytic - numeric).abs() / denom < 0.08,
                "seg {seg} idx {idx}: analytic {analytic} vs numeric {numeric}"
            );
        }
    }

    #[test]
    fn eval_improves_with_training() {
        let (dims, ds) = setup();
        let test = Generator::new(
            &dims,
            &DataConfig { train_samples: 200, avg_nnz: 6.0, ..Default::default() },
        )
        .generate(150, 2);
        let mut m = ModelState::init(&dims, 21);
        let eb = crate::data::batcher::EvalBatches::new(&test, &dims, 64);
        let p_at_1 = |m: &ModelState| {
            let mut hit = 0usize;
            let mut total = 0usize;
            for batch in &eb.batches {
                let preds = eval_ref(m, batch);
                for (r, &id) in batch.sample_ids.iter().enumerate() {
                    total += 1;
                    if test.sample(id as usize).labels.contains(&(preds[r] as u32)) {
                        hit += 1;
                    }
                }
            }
            hit as f64 / total as f64
        };
        let before = p_at_1(&m);
        let mut batcher = Batcher::new(&ds, &dims, 7);
        for _ in 0..150 {
            let b = batcher.next_batch(32, 32);
            sgd_step_ref(&mut m, &b, 0.2);
        }
        let after = p_at_1(&m);
        assert!(after > before + 0.05, "P@1 {before} -> {after}");
    }
}

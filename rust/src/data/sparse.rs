//! CSR-style sparse multi-label dataset storage.
//!
//! Samples are stored in flat arrays with offset tables (CSR), so a 200k ×
//! 76-nnz corpus costs ~2 contiguous allocations instead of 400k Vecs. All
//! invariants (monotone offsets, in-range indices, matching lengths) are
//! enforced by the constructor and checked in tests.

use anyhow::{bail, ensure};

use crate::Result;

/// An immutable sparse multi-label dataset.
#[derive(Clone, Debug)]
pub struct SparseDataset {
    pub num_features: usize,
    pub num_classes: usize,
    /// Feature CSR: sample i owns `feat_idx[feat_off[i]..feat_off[i+1]]`.
    feat_off: Vec<usize>,
    feat_idx: Vec<u32>,
    feat_val: Vec<f32>,
    /// Label CSR.
    lab_off: Vec<usize>,
    lab_idx: Vec<u32>,
}

/// Borrowed view of one sample.
#[derive(Clone, Copy, Debug)]
pub struct SampleView<'a> {
    pub indices: &'a [u32],
    pub values: &'a [f32],
    pub labels: &'a [u32],
}

/// Mutable builder (used by the generator and the libSVM reader).
#[derive(Clone, Debug, Default)]
pub struct DatasetBuilder {
    pub num_features: usize,
    pub num_classes: usize,
    feat_off: Vec<usize>,
    feat_idx: Vec<u32>,
    feat_val: Vec<f32>,
    lab_off: Vec<usize>,
    lab_idx: Vec<u32>,
}

impl DatasetBuilder {
    pub fn new(num_features: usize, num_classes: usize) -> Self {
        DatasetBuilder {
            num_features,
            num_classes,
            feat_off: vec![0],
            lab_off: vec![0],
            ..Default::default()
        }
    }

    /// Append one sample; indices may arrive unsorted, duplicates allowed
    /// (they accumulate in the linear algebra, matching libSVM semantics).
    pub fn push(&mut self, indices: &[u32], values: &[f32], labels: &[u32]) -> Result<()> {
        ensure!(indices.len() == values.len(), "indices/values length mismatch");
        ensure!(!labels.is_empty(), "sample must have at least one label");
        for &i in indices {
            ensure!((i as usize) < self.num_features, "feature index {i} out of range");
        }
        for &l in labels {
            ensure!((l as usize) < self.num_classes, "label {l} out of range");
        }
        self.feat_idx.extend_from_slice(indices);
        self.feat_val.extend_from_slice(values);
        self.feat_off.push(self.feat_idx.len());
        self.lab_idx.extend_from_slice(labels);
        self.lab_off.push(self.lab_idx.len());
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.feat_off.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn finish(self) -> SparseDataset {
        SparseDataset {
            num_features: self.num_features,
            num_classes: self.num_classes,
            feat_off: self.feat_off,
            feat_idx: self.feat_idx,
            feat_val: self.feat_val,
            lab_off: self.lab_off,
            lab_idx: self.lab_idx,
        }
    }
}

impl SparseDataset {
    pub fn len(&self) -> usize {
        self.feat_off.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn sample(&self, i: usize) -> SampleView<'_> {
        let (f0, f1) = (self.feat_off[i], self.feat_off[i + 1]);
        let (l0, l1) = (self.lab_off[i], self.lab_off[i + 1]);
        SampleView {
            indices: &self.feat_idx[f0..f1],
            values: &self.feat_val[f0..f1],
            labels: &self.lab_idx[l0..l1],
        }
    }

    pub fn nnz(&self, i: usize) -> usize {
        self.feat_off[i + 1] - self.feat_off[i]
    }

    pub fn total_nnz(&self) -> usize {
        self.feat_idx.len()
    }

    pub fn avg_nnz(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.feat_idx.len() as f64 / self.len() as f64
        }
    }

    pub fn avg_labels(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.lab_idx.len() as f64 / self.len() as f64
        }
    }

    /// Structural invariant check (used by tests and after deserialization).
    pub fn check(&self) -> Result<()> {
        if self.feat_off.first() != Some(&0) || self.lab_off.first() != Some(&0) {
            bail!("offset tables must start at 0");
        }
        if self.feat_off.len() != self.lab_off.len() {
            bail!("feature/label offset tables disagree on sample count");
        }
        if !self.feat_off.windows(2).all(|w| w[0] <= w[1]) {
            bail!("feature offsets not monotone");
        }
        if !self.lab_off.windows(2).all(|w| w[0] <= w[1]) {
            bail!("label offsets not monotone");
        }
        if *self.feat_off.last().unwrap() != self.feat_idx.len() {
            bail!("feature offsets do not cover storage");
        }
        if *self.lab_off.last().unwrap() != self.lab_idx.len() {
            bail!("label offsets do not cover storage");
        }
        if self.feat_idx.len() != self.feat_val.len() {
            bail!("index/value storage length mismatch");
        }
        if self.feat_idx.iter().any(|&i| i as usize >= self.num_features) {
            bail!("feature index out of range");
        }
        if self.lab_idx.iter().any(|&l| l as usize >= self.num_classes) {
            bail!("label out of range");
        }
        Ok(())
    }

    /// Maximum nnz over all samples (batch padding requirement).
    pub fn max_nnz(&self) -> usize {
        (0..self.len()).map(|i| self.nnz(i)).max().unwrap_or(0)
    }

    /// Maximum labels over all samples.
    pub fn max_labels(&self) -> usize {
        (0..self.len())
            .map(|i| self.lab_off[i + 1] - self.lab_off[i])
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SparseDataset {
        let mut b = DatasetBuilder::new(10, 4);
        b.push(&[1, 3, 5], &[1.0, 2.0, 3.0], &[0]).unwrap();
        b.push(&[0], &[0.5], &[1, 2]).unwrap();
        b.push(&[9, 2], &[1.5, -1.0], &[3]).unwrap();
        b.finish()
    }

    #[test]
    fn builds_and_reads_back() {
        let d = tiny();
        d.check().unwrap();
        assert_eq!(d.len(), 3);
        let s = d.sample(1);
        assert_eq!(s.indices, &[0]);
        assert_eq!(s.values, &[0.5]);
        assert_eq!(s.labels, &[1, 2]);
        assert_eq!(d.nnz(0), 3);
        assert_eq!(d.total_nnz(), 6);
        assert_eq!(d.max_nnz(), 3);
        assert_eq!(d.max_labels(), 2);
        assert!((d.avg_nnz() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_out_of_range() {
        let mut b = DatasetBuilder::new(4, 2);
        assert!(b.push(&[4], &[1.0], &[0]).is_err());
        assert!(b.push(&[0], &[1.0], &[2]).is_err());
        assert!(b.push(&[0, 1], &[1.0], &[0]).is_err());
        assert!(b.push(&[0], &[1.0], &[]).is_err());
    }

    #[test]
    fn empty_dataset_is_consistent() {
        let d = DatasetBuilder::new(1, 1).finish();
        d.check().unwrap();
        assert_eq!(d.len(), 0);
        assert_eq!(d.avg_nnz(), 0.0);
        assert_eq!(d.max_nnz(), 0);
    }
}

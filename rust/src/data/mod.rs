//! Sparse-data substrate: CSR dataset storage, libSVM I/O, the synthetic
//! XML dataset generator (Table 1 substitutes), padded batch assembly, and
//! the [`pipeline`] data plane (sharded ingestion, async prefetch,
//! nnz-aware batch composition) the coordinator trains through.

pub mod batcher;
pub mod libsvm;
pub mod pipeline;
pub mod sparse;
pub mod synthetic;

pub use batcher::{Batcher, PaddedBatch};
pub use pipeline::{DataPlane, ShardedDataset};
pub use sparse::SparseDataset;

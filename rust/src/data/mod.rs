//! Sparse-data substrate: CSR dataset storage, libSVM I/O, the synthetic
//! XML dataset generator (Table 1 substitutes), and padded batch assembly.

pub mod batcher;
pub mod libsvm;
pub mod sparse;
pub mod synthetic;

pub use batcher::{Batcher, PaddedBatch};
pub use sparse::SparseDataset;

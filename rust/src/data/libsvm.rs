//! libSVM / Extreme-Classification-Repository sparse format I/O.
//!
//! The XML repository format (used by Amazon-670k, Delicious-200k) is
//!
//! ```text
//! <num_samples> <num_features> <num_labels>     # header line
//! l1,l2,...  idx:val idx:val ...                # one line per sample
//! ```
//!
//! We read and write exactly that; plain libSVM files without the header are
//! accepted too if dimensions are supplied by the caller.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context};

use super::sparse::{DatasetBuilder, SparseDataset};
use crate::Result;

/// Read an XML-repository file (header required).
pub fn read(path: &Path) -> Result<SparseDataset> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut reader = BufReader::new(file);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    let parts: Vec<usize> = header
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("bad header line: {header:?}"))?;
    if parts.len() != 3 {
        bail!("header must be '<samples> <features> <labels>', got {header:?}");
    }
    let (n, num_features, num_classes) = (parts[0], parts[1], parts[2]);
    let ds = read_body(reader, num_features, num_classes)?;
    if ds.len() != n {
        bail!("header claims {n} samples, file has {}", ds.len());
    }
    Ok(ds)
}

/// Read headerless libSVM lines with caller-supplied dimensions.
pub fn read_headerless(path: &Path, num_features: usize, num_classes: usize) -> Result<SparseDataset> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    read_body(BufReader::new(file), num_features, num_classes)
}

fn read_body<R: BufRead>(reader: R, num_features: usize, num_classes: usize) -> Result<SparseDataset> {
    let mut builder = DatasetBuilder::new(num_features, num_classes);
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (labels, indices, values) =
            parse_line(line).with_context(|| format!("line {}", lineno + 2))?;
        builder
            .push(&indices, &values, &labels)
            .with_context(|| format!("line {}", lineno + 2))?;
    }
    let ds = builder.finish();
    ds.check()?;
    Ok(ds)
}

fn parse_line(line: &str) -> Result<(Vec<u32>, Vec<u32>, Vec<f32>)> {
    let mut tokens = line.split_whitespace();
    let label_tok = tokens.next().context("missing label field")?;
    // A first token containing ':' means the sample has no labels — invalid
    // for training data in this corpus.
    if label_tok.contains(':') {
        bail!("sample without labels");
    }
    let labels: Vec<u32> = label_tok
        .split(',')
        .map(|t| t.trim().parse::<u32>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("bad label field {label_tok:?}"))?;
    let mut indices = Vec::new();
    let mut values = Vec::new();
    for tok in tokens {
        let (i, v) = tok
            .split_once(':')
            .with_context(|| format!("bad feature token {tok:?}"))?;
        indices.push(i.parse::<u32>().with_context(|| format!("bad index {i:?}"))?);
        values.push(v.parse::<f32>().with_context(|| format!("bad value {v:?}"))?);
    }
    Ok((labels, indices, values))
}

/// Write in XML-repository format.
pub fn write(path: &Path, ds: &SparseDataset) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "{} {} {}", ds.len(), ds.num_features, ds.num_classes)?;
    for i in 0..ds.len() {
        let s = ds.sample(i);
        let labels: Vec<String> = s.labels.iter().map(|l| l.to_string()).collect();
        write!(w, "{}", labels.join(","))?;
        for (idx, val) in s.indices.iter().zip(s.values) {
            write!(w, " {idx}:{val}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::DatasetBuilder;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("heterosparse-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip() {
        let mut b = DatasetBuilder::new(100, 10);
        b.push(&[5, 17], &[0.5, 2.25], &[3, 7]).unwrap();
        b.push(&[99], &[-1.0], &[0]).unwrap();
        let ds = b.finish();
        let path = tmpfile("roundtrip.txt");
        write(&path, &ds).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.num_features, 100);
        assert_eq!(back.sample(0).labels, &[3, 7]);
        assert_eq!(back.sample(0).indices, &[5, 17]);
        assert_eq!(back.sample(0).values, &[0.5, 2.25]);
        assert_eq!(back.sample(1).values, &[-1.0]);
    }

    #[test]
    fn parses_xml_repo_line() {
        let (labels, idx, val) = parse_line("12,7 3:0.5 44:1.25").unwrap();
        assert_eq!(labels, vec![12, 7]);
        assert_eq!(idx, vec![3, 44]);
        assert_eq!(val, vec![0.5, 1.25]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("3:0.5 4:1.0").is_err()); // no labels
        assert!(parse_line("1 notafeature").is_err());
        assert!(parse_line("x,y 3:0.5").is_err());
    }

    #[test]
    fn header_mismatch_detected() {
        let path = tmpfile("badheader.txt");
        std::fs::write(&path, "5 10 4\n0 1:1.0\n").unwrap();
        assert!(read(&path).is_err());
    }
}

//! libSVM / Extreme-Classification-Repository sparse format I/O.
//!
//! The XML repository format (used by Amazon-670k, Delicious-200k) is
//!
//! ```text
//! <num_samples> <num_features> <num_labels>     # header line
//! l1,l2,...  idx:val idx:val ...                # one line per sample
//! ```
//!
//! We read and write exactly that; plain libSVM files without the header are
//! accepted too if dimensions are supplied by the caller.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context};

use super::sparse::{DatasetBuilder, SparseDataset};
use crate::Result;

/// Parse the `<samples> <features> <labels>` header line.
fn parse_header(header: &str) -> Result<(usize, usize, usize)> {
    let parts: Vec<usize> = header
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("bad header line: {header:?}"))?;
    if parts.len() != 3 {
        bail!("header must be '<samples> <features> <labels>', got {header:?}");
    }
    Ok((parts[0], parts[1], parts[2]))
}

/// Parse one data line into the builder, with the 1-based file `lineno`
/// attached to any error. Blank lines are skipped (returns false).
fn push_line(builder: &mut DatasetBuilder, line: &str, lineno: usize) -> Result<bool> {
    let line = line.trim();
    if line.is_empty() {
        return Ok(false);
    }
    let (labels, indices, values) =
        parse_line(line).with_context(|| format!("line {lineno}"))?;
    builder
        .push(&indices, &values, &labels)
        .with_context(|| format!("line {lineno}"))?;
    Ok(true)
}

/// Read an XML-repository file (header required).
pub fn read(path: &Path) -> Result<SparseDataset> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut reader = BufReader::new(file);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    let (n, num_features, num_classes) = parse_header(&header)?;
    // Data starts on file line 2 (line 1 is the header).
    let ds = read_body(reader, num_features, num_classes, 2)?;
    if ds.len() != n {
        bail!("header claims {n} samples, file has {}", ds.len());
    }
    Ok(ds)
}

/// Read headerless libSVM lines with caller-supplied dimensions.
pub fn read_headerless(path: &Path, num_features: usize, num_classes: usize) -> Result<SparseDataset> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    // No header line: the first data line IS file line 1.
    read_body(BufReader::new(file), num_features, num_classes, 1)
}

/// `first_lineno` is the 1-based file line the first data line sits on (2
/// for headered files, 1 for headerless), so error contexts point at the
/// real file line in both cases.
fn read_body<R: BufRead>(
    reader: R,
    num_features: usize,
    num_classes: usize,
    first_lineno: usize,
) -> Result<SparseDataset> {
    let mut builder = DatasetBuilder::new(num_features, num_classes);
    for (i, line) in reader.lines().enumerate() {
        push_line(&mut builder, &line?, i + first_lineno)?;
    }
    let ds = builder.finish();
    ds.check()?;
    Ok(ds)
}

/// Read an XML-repository file shard-by-shard: at most `shard_samples`
/// samples are materialized per [`SparseDataset`] shard, so ingestion never
/// holds one whole-corpus CSR. The sharded data plane
/// (`data::pipeline::ShardedDataset::from_libsvm`) builds on this.
pub fn read_shards(
    path: &Path,
    shard_samples: usize,
) -> Result<(Vec<SparseDataset>, usize, usize)> {
    assert!(shard_samples > 0, "shard_samples must be positive");
    let file = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut reader = BufReader::new(file);
    let mut header = String::new();
    reader.read_line(&mut header)?;
    let (n, num_features, num_classes) = parse_header(&header)?;

    let mut shards = Vec::new();
    let mut builder = DatasetBuilder::new(num_features, num_classes);
    let mut total = 0usize;
    for (i, line) in reader.lines().enumerate() {
        // Data starts on file line 2 (line 1 is the header).
        if push_line(&mut builder, &line?, i + 2)? {
            total += 1;
        }
        if builder.len() == shard_samples {
            let fresh = DatasetBuilder::new(num_features, num_classes);
            let shard = std::mem::replace(&mut builder, fresh);
            let ds = shard.finish();
            ds.check()?;
            shards.push(ds);
        }
    }
    if !builder.is_empty() {
        let ds = builder.finish();
        ds.check()?;
        shards.push(ds);
    }
    if total != n {
        bail!("header claims {n} samples, file has {total}");
    }
    Ok((shards, num_features, num_classes))
}

fn parse_line(line: &str) -> Result<(Vec<u32>, Vec<u32>, Vec<f32>)> {
    let mut tokens = line.split_whitespace();
    let label_tok = tokens.next().context("missing label field")?;
    // A first token containing ':' means the sample has no labels — invalid
    // for training data in this corpus.
    if label_tok.contains(':') {
        bail!("sample without labels");
    }
    let labels: Vec<u32> = label_tok
        .split(',')
        .map(|t| t.trim().parse::<u32>())
        .collect::<std::result::Result<_, _>>()
        .with_context(|| format!("bad label field {label_tok:?}"))?;
    let mut indices = Vec::new();
    let mut values = Vec::new();
    for tok in tokens {
        let (i, v) = tok
            .split_once(':')
            .with_context(|| format!("bad feature token {tok:?}"))?;
        indices.push(i.parse::<u32>().with_context(|| format!("bad index {i:?}"))?);
        values.push(v.parse::<f32>().with_context(|| format!("bad value {v:?}"))?);
    }
    Ok((labels, indices, values))
}

/// Write in XML-repository format.
pub fn write(path: &Path, ds: &SparseDataset) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "{} {} {}", ds.len(), ds.num_features, ds.num_classes)?;
    for i in 0..ds.len() {
        let s = ds.sample(i);
        let labels: Vec<String> = s.labels.iter().map(|l| l.to_string()).collect();
        write!(w, "{}", labels.join(","))?;
        for (idx, val) in s.indices.iter().zip(s.values) {
            write!(w, " {idx}:{val}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::DatasetBuilder;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("heterosparse-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip() {
        let mut b = DatasetBuilder::new(100, 10);
        b.push(&[5, 17], &[0.5, 2.25], &[3, 7]).unwrap();
        b.push(&[99], &[-1.0], &[0]).unwrap();
        let ds = b.finish();
        let path = tmpfile("roundtrip.txt");
        write(&path, &ds).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.num_features, 100);
        assert_eq!(back.sample(0).labels, &[3, 7]);
        assert_eq!(back.sample(0).indices, &[5, 17]);
        assert_eq!(back.sample(0).values, &[0.5, 2.25]);
        assert_eq!(back.sample(1).values, &[-1.0]);
    }

    #[test]
    fn parses_xml_repo_line() {
        let (labels, idx, val) = parse_line("12,7 3:0.5 44:1.25").unwrap();
        assert_eq!(labels, vec![12, 7]);
        assert_eq!(idx, vec![3, 44]);
        assert_eq!(val, vec![0.5, 1.25]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("3:0.5 4:1.0").is_err()); // no labels
        assert!(parse_line("1 notafeature").is_err());
        assert!(parse_line("x,y 3:0.5").is_err());
    }

    #[test]
    fn header_mismatch_detected() {
        let path = tmpfile("badheader.txt");
        std::fs::write(&path, "5 10 4\n0 1:1.0\n").unwrap();
        assert!(read(&path).is_err());
    }

    #[test]
    fn error_linenos_account_for_the_header() {
        // The bad line is file line 3 (header + one good line before it).
        let path = tmpfile("lineno-headered.txt");
        std::fs::write(&path, "2 10 4\n0 1:1.0\n0 notafeature\n").unwrap();
        let err = format!("{:#}", read(&path).unwrap_err());
        assert!(err.contains("line 3"), "headered: {err}");
    }

    #[test]
    fn error_linenos_correct_without_header() {
        // Same body, no header: the bad line is file line 2.
        let path = tmpfile("lineno-headerless.txt");
        std::fs::write(&path, "0 1:1.0\n0 notafeature\n").unwrap();
        let err = format!("{:#}", read_headerless(&path, 10, 4).unwrap_err());
        assert!(err.contains("line 2"), "headerless: {err}");
        assert!(!err.contains("line 3"), "off-by-one regression: {err}");
    }

    #[test]
    fn shard_reading_matches_whole_file() {
        let mut b = DatasetBuilder::new(50, 8);
        for i in 0..7u32 {
            b.push(&[i, i + 10], &[1.0, 0.5], &[i % 8]).unwrap();
        }
        let ds = b.finish();
        let path = tmpfile("sharded.txt");
        write(&path, &ds).unwrap();

        let (shards, nf, nc) = read_shards(&path, 3).unwrap();
        assert_eq!((nf, nc), (50, 8));
        assert_eq!(shards.iter().map(|s| s.len()).collect::<Vec<_>>(), vec![3, 3, 1]);
        let mut row = 0usize;
        for shard in &shards {
            for i in 0..shard.len() {
                assert_eq!(shard.sample(i).indices, ds.sample(row).indices);
                assert_eq!(shard.sample(i).labels, ds.sample(row).labels);
                row += 1;
            }
        }
        assert_eq!(row, ds.len());
        // Header sample-count mismatch still detected in shard mode.
        std::fs::write(tmpfile("sharded-bad.txt"), "3 10 4\n0 1:1.0\n").unwrap();
        assert!(read_shards(&tmpfile("sharded-bad.txt"), 2).is_err());
    }
}

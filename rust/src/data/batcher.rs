//! Padded batch assembly for the AOT step executables.
//!
//! The step HLOs have static shapes `(bucket, K)` / `(bucket, L)`; this
//! module turns CSR samples into those padded buffers. Padding rules (must
//! match `python/compile/model.py`):
//!
//! * feature padding: index 0 with value 0.0 (inert in the gather-SpMM),
//! * label padding: label 0 with weight 0.0,
//! * sample padding (bucket > valid): `smask = 0.0` rows that contribute
//!   nothing to the loss or gradient,
//! * label weights are the normalized multi-hot `1/|labels|` (SLIDE-style).
//!
//! The batcher streams the dataset in epoch-shuffled order and reshuffles at
//! wrap-around, so dynamic scheduling can keep drawing batches forever.

use crate::config::ModelDims;
use crate::util::rng::Rng;

use super::sparse::{SampleView, SparseDataset};

/// A batch padded to a static bucket shape, ready for literal upload.
#[derive(Clone, Debug)]
pub struct PaddedBatch {
    /// Static batch dimension (a bucket-grid size).
    pub bucket: usize,
    /// Number of real samples (<= bucket); the rest are masked padding.
    pub valid: usize,
    /// int32[bucket * K] padded feature indices.
    pub idx: Vec<i32>,
    /// f32[bucket * K] padded feature values.
    pub val: Vec<f32>,
    /// int32[bucket * L] padded label indices.
    pub lab: Vec<i32>,
    /// f32[bucket * L] normalized label weights.
    pub lab_w: Vec<f32>,
    /// f32[bucket] sample validity mask.
    pub smask: Vec<f32>,
    /// Total true non-zeros in the batch (drives the cost model, mirroring
    /// the paper's sparse-data-sensitivity observation).
    pub nnz: usize,
    /// Dataset row ids of the real samples (property tests: routing
    /// conservation).
    pub sample_ids: Vec<u32>,
}

impl PaddedBatch {
    /// Freshly allocated all-padding batch of shape `(bucket, k, l)`.
    pub fn with_shape(bucket: usize, k: usize, l: usize) -> PaddedBatch {
        PaddedBatch {
            bucket,
            valid: 0,
            idx: vec![0; bucket * k],
            val: vec![0.0; bucket * k],
            lab: vec![0; bucket * l],
            lab_w: vec![0.0; bucket * l],
            smask: vec![0.0; bucket],
            nnz: 0,
            sample_ids: Vec::new(),
        }
    }

    /// Reshape in place to an all-padding `(bucket, k, l)` batch, keeping
    /// the allocations (the buffer pool's recycle path). Every buffer is
    /// cleared and re-zeroed so a recycled batch is indistinguishable from
    /// a fresh one.
    pub fn reset(&mut self, bucket: usize, k: usize, l: usize) {
        self.bucket = bucket;
        self.valid = 0;
        self.nnz = 0;
        self.sample_ids.clear();
        self.idx.clear();
        self.idx.resize(bucket * k, 0);
        self.val.clear();
        self.val.resize(bucket * k, 0.0);
        self.lab.clear();
        self.lab.resize(bucket * l, 0);
        self.lab_w.clear();
        self.lab_w.resize(bucket * l, 0.0);
        self.smask.clear();
        self.smask.resize(bucket, 0.0);
    }

    pub fn shape_checks(&self, dims: &ModelDims) {
        debug_assert_eq!(self.idx.len(), self.bucket * dims.max_nnz);
        debug_assert_eq!(self.val.len(), self.bucket * dims.max_nnz);
        debug_assert_eq!(self.lab.len(), self.bucket * dims.max_labels);
        debug_assert_eq!(self.lab_w.len(), self.bucket * dims.max_labels);
        debug_assert_eq!(self.smask.len(), self.bucket);
    }
}

/// Pad one CSR sample into row `row` of `batch` (shape `(bucket, k, l)`),
/// applying the padding rules from the module docs. Updates the batch's
/// `nnz`, `smask`, and `sample_ids`; `valid` stays the caller's to manage.
/// Returns the number of features silently *truncated* because the sample
/// carries more than `k` non-zeros — callers surface the count through
/// metrics instead of dropping the tail invisibly.
pub fn pad_sample_into(
    batch: &mut PaddedBatch,
    row: usize,
    id: u32,
    s: &SampleView<'_>,
    k: usize,
    l: usize,
) -> usize {
    let take = s.indices.len().min(k);
    for (j, (&fi, &fv)) in s.indices.iter().zip(s.values).take(take).enumerate() {
        batch.idx[row * k + j] = fi as i32;
        batch.val[row * k + j] = fv;
    }
    batch.nnz += take;
    let nl = s.labels.len().min(l);
    let w = 1.0 / nl as f32;
    for (j, &lb) in s.labels.iter().take(nl).enumerate() {
        batch.lab[row * l + j] = lb as i32;
        batch.lab_w[row * l + j] = w;
    }
    batch.smask[row] = 1.0;
    batch.sample_ids.push(id);
    s.indices.len() - take
}

/// Epoch-shuffled batch stream.
pub struct Batcher<'a> {
    ds: &'a SparseDataset,
    dims: ModelDims,
    order: Vec<u32>,
    cursor: usize,
    rng: Rng,
    /// Monotone count of samples handed out (all epochs).
    pub samples_served: u64,
    /// Monotone count of features dropped because a sample exceeded
    /// `max_nnz` (surfaced through metrics; see `pad_sample_into`).
    pub truncated_features: u64,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a SparseDataset, dims: &ModelDims, seed: u64) -> Self {
        assert!(!ds.is_empty(), "cannot batch an empty dataset");
        let mut rng = Rng::new(seed);
        let mut order: Vec<u32> = (0..ds.len() as u32).collect();
        rng.shuffle(&mut order);
        Batcher {
            ds,
            dims: dims.clone(),
            order,
            cursor: 0,
            rng,
            samples_served: 0,
            truncated_features: 0,
        }
    }

    /// Fraction of the current epoch consumed.
    pub fn epoch_progress(&self) -> f64 {
        self.cursor as f64 / self.order.len() as f64
    }

    /// Assemble the next batch: `valid` real samples padded to `bucket`.
    pub fn next_batch(&mut self, bucket: usize, valid: usize) -> PaddedBatch {
        assert!(valid >= 1 && valid <= bucket, "need 1 <= valid({valid}) <= bucket({bucket})");
        let k = self.dims.max_nnz;
        let l = self.dims.max_labels;
        let mut batch = PaddedBatch::with_shape(bucket, k, l);
        batch.valid = valid;
        for row in 0..valid {
            let id = self.draw();
            let s = self.ds.sample(id as usize);
            self.truncated_features += pad_sample_into(&mut batch, row, id, &s, k, l) as u64;
        }
        self.samples_served += valid as u64;
        batch.shape_checks(&self.dims);
        batch
    }

    fn draw(&mut self) -> u32 {
        if self.cursor >= self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
        }
        let id = self.order[self.cursor];
        self.cursor += 1;
        id
    }
}

/// Padded *evaluation* batches over the test split (fixed bucket; the last
/// batch is mask-padded). Returns per-batch buffers plus the label sets
/// needed for the P@1 check.
pub struct EvalBatches {
    pub bucket: usize,
    pub batches: Vec<PaddedBatch>,
    /// Features dropped because test samples exceeded `max_nnz` — P@1 is
    /// computed on truncated inputs when this is nonzero, so it is
    /// surfaced rather than silently skewing the headline metric.
    pub truncated_features: u64,
}

impl EvalBatches {
    pub fn new(ds: &SparseDataset, dims: &ModelDims, bucket: usize) -> Self {
        let mut batches = Vec::new();
        let k = dims.max_nnz;
        let l = dims.max_labels;
        let mut row = 0usize;
        let mut truncated_features = 0u64;
        while row < ds.len() {
            let valid = (ds.len() - row).min(bucket);
            let mut b = PaddedBatch::with_shape(bucket, k, l);
            b.valid = valid;
            for r in 0..valid {
                let id = (row + r) as u32;
                let s = ds.sample(id as usize);
                truncated_features += pad_sample_into(&mut b, r, id, &s, k, l) as u64;
            }
            batches.push(b);
            row += valid;
        }
        if truncated_features > 0 {
            eprintln!(
                "[eval] warning: test samples exceed model.max_nnz={k}; {truncated_features} \
                 features truncated — P@1 is measured on clipped inputs"
            );
        }
        EvalBatches { bucket, batches, truncated_features }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::data::synthetic::Generator;

    fn dataset() -> (ModelDims, SparseDataset) {
        let dims = ModelDims { features: 256, hidden: 8, classes: 32, max_nnz: 16, max_labels: 4 };
        let cfg = DataConfig { train_samples: 100, avg_nnz: 6.0, ..Default::default() };
        let ds = Generator::new(&dims, &cfg).generate(100, 1);
        (dims, ds)
    }

    #[test]
    fn batch_shapes_and_masks() {
        let (dims, ds) = dataset();
        let mut b = Batcher::new(&ds, &dims, 1);
        let batch = b.next_batch(32, 20);
        assert_eq!(batch.smask.iter().filter(|&&m| m == 1.0).count(), 20);
        assert_eq!(batch.smask[20..].iter().filter(|&&m| m == 0.0).count(), 12);
        assert_eq!(batch.idx.len(), 32 * 16);
        assert_eq!(batch.sample_ids.len(), 20);
        // Padding rows have zero values everywhere.
        for r in 20..32 {
            assert!(batch.val[r * 16..(r + 1) * 16].iter().all(|&v| v == 0.0));
            assert!(batch.lab_w[r * 4..(r + 1) * 4].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn label_weights_normalized_per_sample() {
        let (dims, ds) = dataset();
        let mut b = Batcher::new(&ds, &dims, 2);
        let batch = b.next_batch(16, 16);
        for r in 0..16 {
            let sum: f32 = batch.lab_w[r * 4..(r + 1) * 4].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {r} weight sum {sum}");
        }
    }

    #[test]
    fn epoch_covers_all_samples_before_repeat() {
        let (dims, ds) = dataset();
        let mut b = Batcher::new(&ds, &dims, 3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10 {
            let batch = b.next_batch(10, 10);
            for &id in &batch.sample_ids {
                assert!(seen.insert(id), "sample {id} repeated within epoch");
            }
        }
        assert_eq!(seen.len(), 100);
        // Next draw starts a fresh epoch.
        let batch = b.next_batch(10, 10);
        assert!(batch.sample_ids.iter().all(|id| seen.contains(id)));
    }

    #[test]
    fn nnz_counts_true_nonzeros() {
        let (dims, ds) = dataset();
        let mut b = Batcher::new(&ds, &dims, 4);
        let batch = b.next_batch(8, 8);
        let expected: usize =
            batch.sample_ids.iter().map(|&id| ds.nnz(id as usize).min(dims.max_nnz)).sum();
        assert_eq!(batch.nnz, expected);
    }

    #[test]
    fn truncation_is_counted_not_silent() {
        // max_nnz 4 against samples that can carry up to 16 features.
        let gen_dims =
            ModelDims { features: 256, hidden: 8, classes: 32, max_nnz: 16, max_labels: 4 };
        let cfg = DataConfig { train_samples: 200, avg_nnz: 10.0, ..Default::default() };
        let ds = Generator::new(&gen_dims, &cfg).generate(200, 1);
        let tight = ModelDims { max_nnz: 4, ..gen_dims.clone() };
        let mut b = Batcher::new(&ds, &tight, 1);
        let batch = b.next_batch(64, 64);
        let expected: u64 = batch
            .sample_ids
            .iter()
            .map(|&id| ds.nnz(id as usize).saturating_sub(4) as u64)
            .sum();
        assert!(expected > 0, "test dataset should overflow max_nnz=4");
        assert_eq!(b.truncated_features, expected);
        // Per-row nnz never exceeds the cap.
        assert!(batch.nnz <= 64 * 4);
    }

    #[test]
    fn reset_recycles_to_a_fresh_batch() {
        let (dims, ds) = dataset();
        let mut b = Batcher::new(&ds, &dims, 9);
        let mut batch = b.next_batch(16, 16);
        assert!(batch.nnz > 0);
        batch.reset(8, dims.max_nnz, dims.max_labels);
        assert_eq!(batch.bucket, 8);
        assert_eq!(batch.valid, 0);
        assert_eq!(batch.nnz, 0);
        assert!(batch.sample_ids.is_empty());
        assert!(batch.idx.iter().all(|&v| v == 0));
        assert!(batch.val.iter().all(|&v| v == 0.0));
        assert!(batch.lab.iter().all(|&v| v == 0));
        assert!(batch.lab_w.iter().all(|&v| v == 0.0));
        assert!(batch.smask.iter().all(|&v| v == 0.0));
        batch.shape_checks(&dims);
    }

    #[test]
    fn eval_batches_cover_test_set_once() {
        let (dims, ds) = dataset();
        let eb = EvalBatches::new(&ds, &dims, 32);
        let total: usize = eb.batches.iter().map(|b| b.valid).sum();
        assert_eq!(total, ds.len());
        assert_eq!(eb.batches.len(), 4); // 100 samples / 32 -> 3 full + 1 partial
        assert_eq!(eb.batches[3].valid, 4);
        assert_eq!(eb.truncated_features, 0, "max_nnz fits the generator cap");
    }

    #[test]
    fn eval_truncation_is_counted() {
        let (dims, ds) = dataset();
        let tight = ModelDims { max_nnz: 2, ..dims };
        let eb = EvalBatches::new(&ds, &tight, 32);
        let expected: u64 =
            (0..ds.len()).map(|i| ds.nnz(i).saturating_sub(2) as u64).sum();
        assert!(expected > 0);
        assert_eq!(eb.truncated_features, expected);
    }
}

//! nnz-aware batch composition: epoch-order generation per
//! [`CompositionPolicy`] and the shared [`SampleStream`] every batch —
//! prefetched or synchronous — draws its sample ids from.
//!
//! The stream is the single source of truth for epoch accounting: within
//! one epoch every sample id is *emitted* at most once, no matter how many
//! producers or queues sit downstream. Prefetched batches that get flushed
//! (e.g. a device's bucket size changed before its queue drained) return
//! their ids with [`SampleStream::unget`], carrying the per-draw epoch
//! *runs* [`next_ids`](SampleStream::next_ids) reported — a draw may cross
//! an epoch boundary, so each contiguous run of ids is tagged with its own
//! epoch. Runs from the current epoch are re-queued (those ids will still
//! be served exactly once this epoch); runs from completed epochs are
//! dropped rather than risking a duplicate emission in the new epoch.
//!
//! # Invariants
//!
//! * **Epoch-exact emission**: within one epoch, every sample id is
//!   emitted at most once, across any mix of prefetch queues, flushes,
//!   and `unget` round-trips (property-tested in
//!   `integration_pipeline.rs`).
//! * Emission order is a deterministic function of (policy, seed, unget
//!   sequence) — the virtual-time engines' bit-reproducibility rests on
//!   this.

use crate::config::CompositionPolicy;
use crate::util::rng::Rng;

use super::shard::ShardedDataset;
use std::sync::Arc;

/// Number of nnz-quantile strata the balanced policy interleaves. Any
/// contiguous window of the epoch order of at least this length contains
/// close to one sample per stratum, so batch nnz concentrates around
/// `batch_size × mean_nnz` for every batch size on the bucket grid.
const BALANCE_STRATA: usize = 16;

/// Epoch-ordered sample-id stream over a sharded corpus.
pub struct SampleStream {
    data: Arc<ShardedDataset>,
    policy: CompositionPolicy,
    order: Vec<u32>,
    cursor: usize,
    /// Ids handed back by queue flushes, served before the cursor advances.
    returned: Vec<u32>,
    epoch: u64,
    rng: Rng,
    samples_served: u64,
}

impl SampleStream {
    pub fn new(data: Arc<ShardedDataset>, policy: CompositionPolicy, seed: u64) -> SampleStream {
        assert!(!data.is_empty(), "cannot stream an empty dataset");
        let mut stream = SampleStream {
            data,
            policy,
            order: Vec::new(),
            cursor: 0,
            returned: Vec::new(),
            epoch: 0,
            rng: Rng::new(seed),
            samples_served: 0,
        };
        stream.build_order();
        stream
    }

    pub fn policy(&self) -> CompositionPolicy {
        self.policy
    }

    /// Draw the next `n` sample ids into `out` (cleared first). `runs`
    /// (also cleared) receives the draw's epoch segmentation as
    /// `(epoch, count)` pairs in id order — one pair normally, more when
    /// the draw crosses epoch boundaries. Pass the runs back to [`unget`]
    /// if the batch is flushed unconsumed.
    ///
    /// [`unget`]: SampleStream::unget
    pub fn next_ids(&mut self, n: usize, out: &mut Vec<u32>, runs: &mut Vec<(u64, usize)>) {
        out.clear();
        runs.clear();
        for _ in 0..n {
            let id = match self.returned.pop() {
                // Returned ids always belong to the current epoch (unget
                // filters on that), so tagging with `self.epoch` is exact.
                Some(id) => id,
                None => {
                    if self.cursor >= self.order.len() {
                        self.epoch += 1;
                        self.build_order();
                    }
                    let id = self.order[self.cursor];
                    self.cursor += 1;
                    id
                }
            };
            match runs.last_mut() {
                Some((e, c)) if *e == self.epoch => *c += 1,
                _ => runs.push((self.epoch, 1)),
            }
            out.push(id);
        }
        self.samples_served += n as u64;
    }

    /// Return the unconsumed ids of a flushed prefetch batch, with the
    /// epoch runs its draw reported. Current-epoch runs are re-queued (the
    /// ids will still be served exactly once this epoch); completed-epoch
    /// runs are dropped — their epoch already emitted them, and
    /// re-emitting now would double-serve the id in the current epoch.
    pub fn unget(&mut self, ids: &[u32], runs: &[(u64, usize)]) {
        debug_assert_eq!(runs.iter().map(|&(_, c)| c).sum::<usize>(), ids.len());
        let mut off = 0usize;
        for &(epoch, count) in runs {
            if epoch == self.epoch {
                self.returned.extend_from_slice(&ids[off..off + count]);
                self.samples_served = self.samples_served.saturating_sub(count as u64);
            }
            off += count;
        }
    }

    /// Fraction of the current epoch consumed.
    pub fn epoch_progress(&self) -> f64 {
        let pending = self.returned.len();
        (self.cursor.saturating_sub(pending)) as f64 / self.order.len() as f64
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn samples_served(&self) -> u64 {
        self.samples_served
    }

    fn build_order(&mut self) {
        let n = self.data.len() as u32;
        let mut ids: Vec<u32> = (0..n).collect();
        // Shuffle first so nnz ties land in random order under every policy.
        self.rng.shuffle(&mut ids);
        match self.policy {
            CompositionPolicy::Shuffled => {}
            CompositionPolicy::NnzSorted => {
                ids.sort_by_key(|&i| std::cmp::Reverse(self.data.nnz(i as usize)));
            }
            CompositionPolicy::NnzBalanced => {
                ids = balance_by_nnz(ids, &self.data);
            }
        }
        self.order = ids;
        self.cursor = 0;
    }
}

/// Stratified interleave: sort by nnz, cut into [`BALANCE_STRATA`]
/// quantile strata, then merge the strata at evenly spaced fractional
/// positions (error-diffusion style). Consecutive windows of the result
/// mix all quantiles, so per-batch total nnz hugs `b × mean_nnz`.
fn balance_by_nnz(mut ids: Vec<u32>, data: &ShardedDataset) -> Vec<u32> {
    let n = ids.len();
    if n <= 2 {
        return ids;
    }
    ids.sort_by_key(|&i| data.nnz(i as usize));
    let strata = BALANCE_STRATA.min(n);
    let stratum_size = n.div_ceil(strata);
    let mut keyed: Vec<(f64, u32)> = Vec::with_capacity(n);
    for (s, chunk) in ids.chunks(stratum_size).enumerate() {
        let len = chunk.len() as f64;
        for (j, &id) in chunk.iter().enumerate() {
            // Fractional emission position within the epoch; the tiny
            // stratum-indexed epsilon makes the sort total and stable
            // across strata of equal length.
            keyed.push(((j as f64 + 0.5) / len + s as f64 * 1e-12, id));
        }
    }
    keyed.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    keyed.into_iter().map(|(_, id)| id).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::data::synthetic::Generator;

    fn heavy_tailed(n: usize) -> Arc<ShardedDataset> {
        let dims = ModelDims { features: 512, hidden: 8, classes: 32, max_nnz: 64, max_labels: 4 };
        let cfg = DataConfig {
            train_samples: n,
            avg_nnz: 10.0,
            nnz_sigma: 1.2, // heavy tail: nnz spans ~1..64
            ..Default::default()
        };
        let ds = Generator::new(&dims, &cfg).generate(n, 1);
        Arc::new(ShardedDataset::from_dataset(&ds, 128))
    }

    fn epoch_ids(stream: &mut SampleStream, n: usize) -> Vec<u32> {
        let mut out = Vec::new();
        let mut buf = Vec::new();
        let mut runs = Vec::new();
        while out.len() < n {
            stream.next_ids(25.min(n - out.len()), &mut buf, &mut runs);
            out.extend_from_slice(&buf);
        }
        out
    }

    #[test]
    fn every_policy_emits_each_id_once_per_epoch() {
        let data = heavy_tailed(400);
        for policy in CompositionPolicy::all() {
            let mut stream = SampleStream::new(data.clone(), policy, 7);
            let ids = epoch_ids(&mut stream, 400);
            let mut sorted = ids.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 400, "{policy:?} epoch must cover every sample once");
            assert_eq!(stream.epoch(), 0, "epoch 0 not over until sample 401");
            // The next draw starts epoch 1 and re-covers everything.
            let ids2 = epoch_ids(&mut stream, 400);
            let mut sorted2 = ids2.clone();
            sorted2.sort_unstable();
            sorted2.dedup();
            assert_eq!(sorted2.len(), 400, "{policy:?} epoch 1 re-covers the corpus");
            assert_eq!(stream.epoch(), 1);
        }
    }

    #[test]
    fn unget_reserves_ids_within_the_epoch() {
        let data = heavy_tailed(100);
        let mut stream = SampleStream::new(data, CompositionPolicy::Shuffled, 3);
        let mut buf = Vec::new();
        let mut runs = Vec::new();
        stream.next_ids(10, &mut buf, &mut runs);
        assert_eq!(runs, vec![(0, 10)]);
        let flushed = buf.clone();
        stream.unget(&flushed, &runs);
        // The whole epoch still comes out exactly once.
        let ids = epoch_ids(&mut stream, 100);
        let mut sorted = ids;
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 100);
    }

    #[test]
    fn stale_epoch_unget_is_dropped() {
        let data = heavy_tailed(50);
        let mut stream = SampleStream::new(data, CompositionPolicy::Shuffled, 5);
        let mut buf = Vec::new();
        let mut runs = Vec::new();
        stream.next_ids(10, &mut buf, &mut runs);
        let held = buf.clone();
        let held_runs = runs.clone();
        epoch_ids(&mut stream, 40); // finish epoch 0
        stream.next_ids(5, &mut buf, &mut runs); // now in epoch 1
        stream.unget(&held, &held_runs);
        // Epoch 1 must still be duplicate-free.
        let mut seen: Vec<u32> = buf.clone();
        seen.extend(epoch_ids(&mut stream, 45));
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), seen.len(), "stale unget leaked a duplicate into epoch 1");
    }

    #[test]
    fn boundary_spanning_flush_requeues_only_current_epoch_ids() {
        // 50-sample corpus; draw 45, then a 10-draw that spans the
        // boundary (5 from epoch 0, 5 from epoch 1). Flushing that batch
        // must re-queue ONLY the epoch-1 ids — epoch 1 then still serves
        // every id exactly once, and epoch 0's tail is dropped, not
        // double-served.
        let data = heavy_tailed(50);
        let mut stream = SampleStream::new(data, CompositionPolicy::Shuffled, 9);
        epoch_ids(&mut stream, 45);
        let mut buf = Vec::new();
        let mut runs = Vec::new();
        stream.next_ids(10, &mut buf, &mut runs);
        assert_eq!(runs, vec![(0, 5), (1, 5)], "draw must report the epoch split");
        let epoch1_part: Vec<u32> = buf[5..].to_vec();
        stream.unget(&buf, &runs);

        // Epoch 1: 50 distinct ids total, including the re-queued five.
        let e1 = epoch_ids(&mut stream, 50);
        let mut sorted = e1.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 50, "epoch 1 must cover the corpus exactly once");
        // The re-queued ids come back first (LIFO returned pile).
        for id in epoch1_part {
            assert!(e1[..5].contains(&id), "re-queued epoch-1 id {id} must be served first");
        }
        assert_eq!(stream.epoch(), 1);
    }

    #[test]
    fn balanced_order_flattens_windowed_nnz() {
        let data = heavy_tailed(1024);
        let window = 64usize;
        let cv = |policy: CompositionPolicy| {
            let mut stream = SampleStream::new(data.clone(), policy, 11);
            let ids = epoch_ids(&mut stream, 1024);
            let sums: Vec<f64> = ids
                .chunks(window)
                .map(|c| c.iter().map(|&i| data.nnz(i as usize) as f64).sum())
                .collect();
            let mean = sums.iter().sum::<f64>() / sums.len() as f64;
            let var =
                sums.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / sums.len() as f64;
            var.sqrt() / mean
        };
        let shuffled = cv(CompositionPolicy::Shuffled);
        let balanced = cv(CompositionPolicy::NnzBalanced);
        let sorted = cv(CompositionPolicy::NnzSorted);
        assert!(
            balanced < shuffled * 0.5,
            "balanced CV {balanced:.4} should be well under shuffled {shuffled:.4}"
        );
        assert!(sorted > shuffled, "sorted is the stress policy: {sorted:.4} vs {shuffled:.4}");
    }

    #[test]
    fn epochs_reshuffle_between_iterations() {
        let data = heavy_tailed(200);
        let mut stream = SampleStream::new(data, CompositionPolicy::Shuffled, 13);
        let e0 = epoch_ids(&mut stream, 200);
        let e1 = epoch_ids(&mut stream, 200);
        assert_ne!(e0, e1, "epochs must reshuffle");
    }
}

//! Recycling pool for [`PaddedBatch`] allocations.
//!
//! The old hot path `vec!`-ed four buffers for every batch (idx, val, lab,
//! lab_w — plus smask); at thousands of batches per second that is pure
//! allocator traffic. The pool hands those allocations back and forth
//! between producers and consumers instead. Every `get` returns a batch
//! that is bit-for-bit indistinguishable from a freshly allocated one
//! (`PaddedBatch::reset` clears and re-zeroes every buffer) — the
//! never-hand-out-stale-state property is pinned by tests here and in
//! `tests/integration_pipeline.rs`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::data::batcher::PaddedBatch;

/// Thread-safe batch-buffer pool (shared via `Arc` between the data plane,
/// its producer threads, and the engine consumers).
#[derive(Debug)]
pub struct BufferPool {
    free: Mutex<Vec<PaddedBatch>>,
    /// Retention cap: `put` beyond this drops the buffer instead of
    /// growing the free list without bound. Grows monotonically via
    /// [`ensure_retention`](BufferPool::ensure_retention) as the data
    /// plane learns its real working set (slots × depth + in-flight).
    max_retained: AtomicUsize,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Counter snapshot for telemetry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `get` calls served by recycling a retained buffer.
    pub hits: u64,
    /// `get` calls that had to allocate fresh buffers.
    pub misses: u64,
    /// Buffers currently retained.
    pub retained: usize,
}

impl BufferPool {
    pub fn new(max_retained: usize) -> BufferPool {
        BufferPool {
            free: Mutex::new(Vec::new()),
            max_retained: AtomicUsize::new(max_retained.max(1)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Grow the retention cap to at least `n` (never shrinks — buffers
    /// already in circulation should always find their way back).
    pub fn ensure_retention(&self, n: usize) {
        self.max_retained.fetch_max(n, Ordering::Relaxed);
    }

    /// Take a cleared all-padding batch of shape `(bucket, k, l)`,
    /// recycling a retained allocation when one is available.
    pub fn get(&self, bucket: usize, k: usize, l: usize) -> PaddedBatch {
        let recycled = self.free.lock().unwrap().pop();
        match recycled {
            Some(mut b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b.reset(bucket, k, l);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                PaddedBatch::with_shape(bucket, k, l)
            }
        }
    }

    /// Return a consumed batch's allocations to the pool.
    pub fn put(&self, batch: PaddedBatch) {
        let cap = self.max_retained.load(Ordering::Relaxed);
        let mut free = self.free.lock().unwrap();
        if free.len() < cap {
            free.push(batch);
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            retained: self.free.lock().unwrap().len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dirty_batch(bucket: usize, k: usize, l: usize) -> PaddedBatch {
        let mut b = PaddedBatch::with_shape(bucket, k, l);
        b.valid = bucket;
        b.nnz = 999;
        b.idx.fill(7);
        b.val.fill(3.25);
        b.lab.fill(5);
        b.lab_w.fill(0.5);
        b.smask.fill(1.0);
        b.sample_ids.extend(0..bucket as u32);
        b
    }

    #[test]
    fn recycled_batches_are_clean() {
        let pool = BufferPool::new(8);
        pool.put(dirty_batch(32, 16, 4));
        let b = pool.get(32, 16, 4);
        assert_eq!(b.valid, 0);
        assert_eq!(b.nnz, 0);
        assert!(b.sample_ids.is_empty());
        assert!(b.idx.iter().all(|&v| v == 0));
        assert!(b.val.iter().all(|&v| v == 0.0));
        assert!(b.lab.iter().all(|&v| v == 0));
        assert!(b.lab_w.iter().all(|&v| v == 0.0));
        assert!(b.smask.iter().all(|&v| v == 0.0));
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn reshapes_across_bucket_sizes() {
        let pool = BufferPool::new(8);
        pool.put(dirty_batch(128, 32, 8));
        let b = pool.get(16, 4, 2);
        assert_eq!(b.bucket, 16);
        assert_eq!(b.idx.len(), 16 * 4);
        assert_eq!(b.lab_w.len(), 16 * 2);
        assert!(b.idx.iter().all(|&v| v == 0));
        // Growing again also re-zeroes the reused capacity.
        pool.put(b);
        let big = pool.get(64, 8, 4);
        assert_eq!(big.idx.len(), 64 * 8);
        assert!(big.val.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn retention_grows_but_never_shrinks() {
        let pool = BufferPool::new(1);
        pool.ensure_retention(3);
        pool.ensure_retention(2); // no-op: monotone
        for _ in 0..5 {
            pool.put(dirty_batch(4, 2, 1));
        }
        assert_eq!(pool.stats().retained, 3);
    }

    #[test]
    fn retention_is_bounded_and_stats_track() {
        let pool = BufferPool::new(2);
        assert_eq!(pool.get(8, 2, 1).bucket, 8); // miss
        for _ in 0..5 {
            pool.put(dirty_batch(8, 2, 1));
        }
        let s = pool.stats();
        assert_eq!(s.retained, 2, "retention cap enforced");
        assert_eq!(s.misses, 1);
        pool.get(8, 2, 1);
        pool.get(8, 2, 1);
        pool.get(8, 2, 1);
        let s = pool.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 2);
        assert_eq!(s.retained, 0);
    }
}

//! Sharded ingestion: a corpus as a sequence of bounded CSR shards, each
//! carrying an nnz-histogram manifest.
//!
//! Splitting the corpus buys three things: (1) ingestion never needs the
//! whole corpus resident as one CSR (the libSVM reader materializes one
//! shard at a time), (2) the per-shard manifests summarize nnz statistics —
//! [`ShardedDataset::mean_nnz`] sums them instead of rescanning samples,
//! and the histograms are the per-shard cost profile telemetry and future
//! shard-placement decisions read (the *clamped* estimate feeding
//! `DispatchPlan.nnz_estimate` still scans once, since clamping depends on
//! `max_nnz`), and (3) shards are the natural unit for future distribution
//! (DESIGN.md north star).
//!
//! Samples keep *global* ids (`0..len`) across shards so epoch-conservation
//! properties and routing telemetry are shard-agnostic.

use std::path::Path;

use anyhow::{bail, ensure};

use crate::data::sparse::{DatasetBuilder, SampleView, SparseDataset};
use crate::Result;

/// Histogram buckets in the shard manifest: bucket `i` counts samples whose
/// nnz falls in `[2^i, 2^(i+1))` (bucket 0 additionally catches nnz 0).
pub const NNZ_HIST_BUCKETS: usize = 16;

/// Per-shard nnz statistics, computed once at ingestion.
#[derive(Clone, Debug)]
pub struct ShardMeta {
    pub samples: usize,
    pub total_nnz: u64,
    pub min_nnz: usize,
    pub max_nnz: usize,
    /// log2-bucketed nnz-per-sample histogram.
    pub nnz_hist: [u32; NNZ_HIST_BUCKETS],
}

impl ShardMeta {
    pub fn from_shard(ds: &SparseDataset) -> ShardMeta {
        let mut meta = ShardMeta {
            samples: ds.len(),
            total_nnz: 0,
            min_nnz: usize::MAX,
            max_nnz: 0,
            nnz_hist: [0; NNZ_HIST_BUCKETS],
        };
        for i in 0..ds.len() {
            let nnz = ds.nnz(i);
            meta.total_nnz += nnz as u64;
            meta.min_nnz = meta.min_nnz.min(nnz);
            meta.max_nnz = meta.max_nnz.max(nnz);
            meta.nnz_hist[hist_bucket(nnz)] += 1;
        }
        if ds.is_empty() {
            meta.min_nnz = 0;
        }
        meta
    }

    pub fn mean_nnz(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.total_nnz as f64 / self.samples as f64
        }
    }
}

/// Which histogram bucket an nnz count lands in.
pub fn hist_bucket(nnz: usize) -> usize {
    if nnz <= 1 {
        0
    } else {
        ((usize::BITS - 1 - nnz.leading_zeros()) as usize).min(NNZ_HIST_BUCKETS - 1)
    }
}

/// A corpus stored as bounded shards with per-shard manifests. Immutable
/// after construction; shared across producer threads via `Arc`.
#[derive(Clone, Debug)]
pub struct ShardedDataset {
    pub num_features: usize,
    pub num_classes: usize,
    shards: Vec<SparseDataset>,
    metas: Vec<ShardMeta>,
    /// Global sample id of each shard's first sample, plus the total at the
    /// end: shard of global id `g` = partition point over this table.
    starts: Vec<usize>,
}

impl ShardedDataset {
    /// Split an in-memory dataset into shards of at most `shard_samples`
    /// samples (the synthetic-generator path).
    pub fn from_dataset(ds: &SparseDataset, shard_samples: usize) -> ShardedDataset {
        assert!(shard_samples > 0, "shard_samples must be positive");
        let mut shards = Vec::new();
        let mut row = 0usize;
        while row < ds.len() {
            let take = (ds.len() - row).min(shard_samples);
            let mut b = DatasetBuilder::new(ds.num_features, ds.num_classes);
            for i in row..row + take {
                let s = ds.sample(i);
                b.push(s.indices, s.values, s.labels)
                    .expect("resharding a valid dataset cannot fail");
            }
            shards.push(b.finish());
            row += take;
        }
        Self::from_shards(shards, ds.num_features, ds.num_classes)
            .expect("shards from one dataset are consistent")
    }

    /// Assemble from already-loaded shards (the libSVM shard reader path).
    pub fn from_shards(
        shards: Vec<SparseDataset>,
        num_features: usize,
        num_classes: usize,
    ) -> Result<ShardedDataset> {
        for s in &shards {
            ensure!(
                s.num_features == num_features && s.num_classes == num_classes,
                "shard dimensions disagree with the corpus ({}x{} vs {num_features}x{num_classes})",
                s.num_features,
                s.num_classes
            );
        }
        let metas: Vec<ShardMeta> = shards.iter().map(ShardMeta::from_shard).collect();
        let mut starts = Vec::with_capacity(shards.len() + 1);
        let mut acc = 0usize;
        for s in &shards {
            starts.push(acc);
            acc += s.len();
        }
        starts.push(acc);
        if acc == 0 {
            bail!("sharded dataset has no samples");
        }
        Ok(ShardedDataset { num_features, num_classes, shards, metas, starts })
    }

    /// Shard-by-shard libSVM ingestion (XML-repository header format).
    pub fn from_libsvm(path: &Path, shard_samples: usize) -> Result<ShardedDataset> {
        let (shards, num_features, num_classes) =
            crate::data::libsvm::read_shards(path, shard_samples)?;
        Self::from_shards(shards, num_features, num_classes)
    }

    pub fn len(&self) -> usize {
        *self.starts.last().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, i: usize) -> &SparseDataset {
        &self.shards[i]
    }

    /// The per-shard nnz manifests.
    pub fn manifest(&self) -> &[ShardMeta] {
        &self.metas
    }

    /// Locate a global sample id: (shard index, offset within the shard).
    fn locate(&self, global: usize) -> (usize, usize) {
        debug_assert!(global < self.len(), "sample {global} out of range");
        // First shard whose start exceeds `global`, minus one.
        let shard = self.starts.partition_point(|&s| s <= global) - 1;
        (shard, global - self.starts[shard])
    }

    pub fn sample(&self, global: usize) -> SampleView<'_> {
        let (s, off) = self.locate(global);
        self.shards[s].sample(off)
    }

    pub fn nnz(&self, global: usize) -> usize {
        let (s, off) = self.locate(global);
        self.shards[s].nnz(off)
    }

    /// Corpus mean nnz per sample, straight off the manifests.
    pub fn mean_nnz(&self) -> f64 {
        let total: u64 = self.metas.iter().map(|m| m.total_nnz).sum();
        total as f64 / self.len() as f64
    }

    /// Mean nnz per sample after clamping every sample to `max_nnz` — the
    /// per-batch cost estimate the dispatch plan consumes (clamping mirrors
    /// what padding actually feeds the device).
    pub fn mean_nnz_clamped(&self, max_nnz: usize) -> f64 {
        let total: u64 =
            (0..self.len()).map(|i| self.nnz(i).min(max_nnz) as u64).sum();
        total as f64 / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::data::synthetic::Generator;

    fn corpus(n: usize) -> SparseDataset {
        let dims = ModelDims { features: 256, hidden: 8, classes: 32, max_nnz: 24, max_labels: 4 };
        let cfg =
            DataConfig { train_samples: n, avg_nnz: 8.0, nnz_sigma: 0.9, ..Default::default() };
        Generator::new(&dims, &cfg).generate(n, 1)
    }

    #[test]
    fn sharding_preserves_every_sample_globally() {
        let ds = corpus(250);
        let sharded = ShardedDataset::from_dataset(&ds, 64);
        assert_eq!(sharded.len(), 250);
        assert_eq!(sharded.num_shards(), 4); // 64+64+64+58
        assert_eq!(sharded.shard(3).len(), 58);
        for i in 0..ds.len() {
            assert_eq!(sharded.sample(i).indices, ds.sample(i).indices, "sample {i}");
            assert_eq!(sharded.sample(i).labels, ds.sample(i).labels, "sample {i}");
            assert_eq!(sharded.nnz(i), ds.nnz(i));
        }
    }

    #[test]
    fn manifests_summarize_shards() {
        let ds = corpus(200);
        let sharded = ShardedDataset::from_dataset(&ds, 100);
        let manifest = sharded.manifest();
        assert_eq!(manifest.len(), 2);
        for (s, meta) in manifest.iter().enumerate() {
            assert_eq!(meta.samples, 100);
            let hist_total: u32 = meta.nnz_hist.iter().sum();
            assert_eq!(hist_total as usize, meta.samples, "shard {s} histogram covers all samples");
            assert!(meta.min_nnz <= meta.max_nnz);
            assert!(meta.mean_nnz() > 0.0);
        }
        let total: u64 = manifest.iter().map(|m| m.total_nnz).sum();
        assert_eq!(total as usize, ds.total_nnz());
        assert!((sharded.mean_nnz() - ds.avg_nnz()).abs() < 1e-12);
    }

    #[test]
    fn clamped_mean_tracks_padding_cost() {
        let ds = corpus(300);
        let sharded = ShardedDataset::from_dataset(&ds, 128);
        let clamped = sharded.mean_nnz_clamped(4);
        assert!(clamped <= 4.0);
        assert!(clamped <= sharded.mean_nnz());
        assert!((sharded.mean_nnz_clamped(10_000) - sharded.mean_nnz()).abs() < 1e-12);
    }

    #[test]
    fn hist_buckets_are_log2() {
        assert_eq!(hist_bucket(0), 0);
        assert_eq!(hist_bucket(1), 0);
        assert_eq!(hist_bucket(2), 1);
        assert_eq!(hist_bucket(3), 1);
        assert_eq!(hist_bucket(4), 2);
        assert_eq!(hist_bucket(1023), 9);
        assert_eq!(hist_bucket(1024), 10);
    }

    #[test]
    fn inconsistent_shards_rejected() {
        let a = DatasetBuilder::new(10, 4);
        let b = DatasetBuilder::new(20, 4);
        let mut a = a;
        a.push(&[1], &[1.0], &[0]).unwrap();
        let mut b = b;
        b.push(&[1], &[1.0], &[0]).unwrap();
        assert!(ShardedDataset::from_shards(vec![a.finish(), b.finish()], 10, 4).is_err());
        assert!(ShardedDataset::from_shards(vec![], 10, 4).is_err());
    }
}

//! The [`DataPlane`]: the producer/consumer boundary between datasets and
//! the coordinator.
//!
//! Consumers (the execution engines) pull [`PaddedBatch`]es per device
//! slot; batches come either from bounded per-slot prefetch queues filled
//! by background producer threads (the threaded real-time engine) or from
//! synchronous assembly on the calling thread (the virtual-time engine,
//! which must stay deterministic — producer interleaving would perturb the
//! sample→device routing). Both paths draw ids from one [`SampleStream`]
//! (epoch accounting, composition policy) and lease buffers from one
//! [`BufferPool`] (allocation recycling); consumed batches come back via
//! [`DataPlane::recycle`].
//!
//! Queue protocol: [`DataPlane::begin_window`] declares the per-slot bucket
//! sizes for the next mega-batch. Queues whose bucket changed are flushed —
//! their sample ids go back to the stream (per-epoch-run filtering, see
//! `compose.rs`) and their buffers to the pool. The consumer hot path never
//! blocks: an empty queue counts a *starvation* event and falls back to
//! synchronous assembly, so prefetch is a throughput optimization, never a
//! correctness dependency.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::config::{CompositionPolicy, ModelDims, PipelineConfig};
use crate::data::batcher::{pad_sample_into, PaddedBatch};
use crate::obs::{CounterHandle, ObsHandle};

use super::buffer_pool::{BufferPool, PoolStats};
use super::compose::SampleStream;
use super::shard::ShardedDataset;

/// Cumulative data-plane counters (snapshot via [`DataPlane::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineStats {
    /// Batches served straight from a prefetch queue.
    pub prefetched: u64,
    /// Batches assembled synchronously on the consumer thread.
    pub synchronous: u64,
    /// Consumer hits on an empty prefetch queue (starvation events).
    pub starved: u64,
    /// Prefetched batches flushed by a bucket reconfiguration.
    pub flushed: u64,
    /// Features dropped because samples exceeded `max_nnz`.
    pub truncated_features: u64,
    /// Buffer-pool counters.
    pub pool: PoolStats,
}

/// Epoch segmentation of one batch's id draw (see `SampleStream::next_ids`).
type EpochRuns = Vec<(u64, usize)>;

struct SlotQueue {
    /// Bucket size this queue prefetches for (0 = unconfigured, idle).
    bucket: usize,
    /// Ready batches with their draw's epoch runs (for unget on flush).
    ready: VecDeque<(PaddedBatch, EpochRuns)>,
    /// Producer reservations currently being assembled for this slot.
    pending: usize,
}

impl SlotQueue {
    fn idle() -> SlotQueue {
        SlotQueue { bucket: 0, ready: VecDeque::new(), pending: 0 }
    }
}

struct Shared {
    data: Arc<ShardedDataset>,
    dims: ModelDims,
    depth: usize,
    stream: Mutex<SampleStream>,
    pool: BufferPool,
    slots: Mutex<Vec<SlotQueue>>,
    /// Producers park here when every queue is full (or none configured).
    work: Condvar,
    shutdown: AtomicBool,
    // Registry-backed counters (`data.*` dotted names) — the same atomics
    // behind [`DataPlane::stats`] and the RunLog metrics snapshot, so the
    // legacy columns and the obs export can never disagree.
    prefetched: CounterHandle,
    synchronous: CounterHandle,
    starved: CounterHandle,
    flushed: CounterHandle,
    truncated: CounterHandle,
    truncation_warned: AtomicBool,
}

impl Shared {
    /// Draw `valid` ids and assemble them into a pooled `(bucket, K, L)`
    /// batch. The stream lock is held only for the id draw; padding — the
    /// expensive part — runs outside it so producers overlap.
    fn assemble(&self, bucket: usize, valid: usize) -> (PaddedBatch, EpochRuns) {
        let k = self.dims.max_nnz;
        let l = self.dims.max_labels;
        let mut batch = self.pool.get(bucket, k, l);
        let mut ids = Vec::with_capacity(valid);
        let mut runs = EpochRuns::new();
        self.stream.lock().unwrap().next_ids(valid, &mut ids, &mut runs);
        let mut truncated = 0usize;
        for (row, &id) in ids.iter().enumerate() {
            let s = self.data.sample(id as usize);
            truncated += pad_sample_into(&mut batch, row, id, &s, k, l);
        }
        batch.valid = valid;
        if truncated > 0 {
            self.truncated.add(truncated as u64);
            if !self.truncation_warned.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "[data-plane] warning: samples exceed model.max_nnz={k}; feature tails are \
                     being truncated (count surfaced in metrics as truncated_features)"
                );
            }
        }
        batch.shape_checks(&self.dims);
        (batch, runs)
    }

    /// Give a flushed batch's ids back to the stream and its buffers to
    /// the pool. Call WITHOUT holding the slots lock (lock order: slots
    /// before stream never both).
    fn abandon(&self, batch: PaddedBatch, runs: EpochRuns) {
        self.flushed.inc();
        self.stream.lock().unwrap().unget(&batch.sample_ids, &runs);
        self.pool.put(batch);
    }
}

/// Handle the trainer owns and the engines consume from.
pub struct DataPlane {
    shared: Arc<Shared>,
    producers: Vec<std::thread::JoinHandle<()>>,
    /// Mean nnz per sample after `max_nnz` clamping, computed once at
    /// construction (one corpus scan).
    nnz_estimate: f64,
}

impl DataPlane {
    /// Build a plane over a sharded corpus. `producer_threads` > 0 enables
    /// async prefetch; 0 keeps every batch assembly on the consumer thread
    /// (required for deterministic virtual-time runs — the trainer passes 0
    /// whenever `runtime.mode = "virtual"`).
    pub fn new(
        data: Arc<ShardedDataset>,
        dims: &ModelDims,
        pcfg: &PipelineConfig,
        producer_threads: usize,
        seed: u64,
    ) -> DataPlane {
        DataPlane::new_obs(data, dims, pcfg, producer_threads, seed, &ObsHandle::disabled())
    }

    /// [`DataPlane::new`] with the plane's counters registered in `obs`'s
    /// registry under `data.*` dotted names — the trainer passes its
    /// session handle so pipeline counters land in the RunLog metrics
    /// snapshot alongside every other subsystem's.
    pub fn new_obs(
        data: Arc<ShardedDataset>,
        dims: &ModelDims,
        pcfg: &PipelineConfig,
        producer_threads: usize,
        seed: u64,
        obs: &ObsHandle,
    ) -> DataPlane {
        let stream = SampleStream::new(data.clone(), pcfg.policy, seed);
        // Initial retention guess; `begin_window` grows it to the real
        // working set once the slot count is known.
        let retain = pcfg.queue_depth * 4 + producer_threads + 4;
        let shared = Arc::new(Shared {
            data,
            dims: dims.clone(),
            depth: pcfg.queue_depth,
            stream: Mutex::new(stream),
            pool: BufferPool::new(retain),
            slots: Mutex::new(Vec::new()),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
            prefetched: obs.counter("data.prefetched"),
            synchronous: obs.counter("data.synchronous"),
            starved: obs.counter("data.starved"),
            flushed: obs.counter("data.flushed"),
            truncated: obs.counter("data.truncated_features"),
            truncation_warned: AtomicBool::new(false),
        });
        let producers = (0..producer_threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("data-producer-{i}"))
                    .spawn(move || producer_main(shared, i))
                    .expect("spawning data-plane producer")
            })
            .collect();
        let nnz_estimate = shared.data.mean_nnz_clamped(shared.dims.max_nnz);
        DataPlane { shared, producers, nnz_estimate }
    }

    /// Synchronous plane with defaults except the policy — test/tool sugar.
    pub fn new_sync(
        data: Arc<ShardedDataset>,
        dims: &ModelDims,
        policy: CompositionPolicy,
        seed: u64,
    ) -> DataPlane {
        let pcfg = PipelineConfig { policy, ..PipelineConfig::default() };
        DataPlane::new(data, dims, &pcfg, 0, seed)
    }

    /// True when producer threads are prefetching.
    pub fn is_async(&self) -> bool {
        !self.producers.is_empty()
    }

    /// Declare the per-slot bucket sizes for the next dispatch window
    /// (engines call this at every mega-batch start). Queues whose bucket
    /// changed are flushed; their ids return to the stream.
    pub fn begin_window(&self, buckets: &[usize]) {
        // Retain enough buffers for every queue at full depth plus one
        // in-flight batch per slot, producer, and consumer.
        self.shared.pool.ensure_retention(
            buckets.len() * (self.shared.depth + 2) + self.producers.len() + 4,
        );
        let mut flushed: Vec<(PaddedBatch, EpochRuns)> = Vec::new();
        {
            let mut slots = self.shared.slots.lock().unwrap();
            if slots.len() > buckets.len() {
                for q in slots.drain(buckets.len()..) {
                    flushed.extend(q.ready);
                }
            }
            while slots.len() < buckets.len() {
                slots.push(SlotQueue::idle());
            }
            for (q, &b) in slots.iter_mut().zip(buckets) {
                if q.bucket != b {
                    flushed.extend(q.ready.drain(..));
                    q.bucket = b;
                }
            }
        }
        for (batch, runs) in flushed {
            self.shared.abandon(batch, runs);
        }
        self.shared.work.notify_all();
    }

    /// Pull the next batch for device slot `slot`: `valid` real samples
    /// padded to `bucket`. Full batches come from the slot's prefetch
    /// queue when possible; partial batches (the dynamic budget tail) and
    /// starved or synchronous paths assemble on this thread.
    pub fn next_batch_for(&self, slot: usize, bucket: usize, valid: usize) -> PaddedBatch {
        assert!(valid >= 1 && valid <= bucket, "need 1 <= valid({valid}) <= bucket({bucket})");
        if self.is_async() && valid == bucket {
            let popped = {
                let mut slots = self.shared.slots.lock().unwrap();
                match slots.get_mut(slot) {
                    Some(q) if q.bucket == bucket => match q.ready.pop_front() {
                        Some((batch, _runs)) => Some(batch),
                        None => {
                            self.shared.starved.inc();
                            None
                        }
                    },
                    _ => None,
                }
            };
            if let Some(batch) = popped {
                self.shared.prefetched.inc();
                self.shared.work.notify_one();
                return batch;
            }
        }
        self.shared.synchronous.inc();
        self.shared.assemble(bucket, valid).0
    }

    /// Slot-less synchronous pull (eval tooling, benches).
    pub fn next_batch(&self, bucket: usize, valid: usize) -> PaddedBatch {
        assert!(valid >= 1 && valid <= bucket, "need 1 <= valid({valid}) <= bucket({bucket})");
        self.shared.synchronous.inc();
        self.shared.assemble(bucket, valid).0
    }

    /// Return a consumed batch's allocations to the buffer pool.
    pub fn recycle(&self, batch: PaddedBatch) {
        self.shared.pool.put(batch);
    }

    /// Mean nnz per sample after `max_nnz` clamping — the per-batch cost
    /// estimate the dispatch plan consumes (computed once at construction).
    pub fn nnz_estimate(&self) -> f64 {
        self.nnz_estimate
    }

    pub fn epoch_progress(&self) -> f64 {
        self.shared.stream.lock().unwrap().epoch_progress()
    }

    pub fn samples_served(&self) -> u64 {
        self.shared.stream.lock().unwrap().samples_served()
    }

    pub fn policy(&self) -> CompositionPolicy {
        self.shared.stream.lock().unwrap().policy()
    }

    pub fn data(&self) -> &Arc<ShardedDataset> {
        &self.shared.data
    }

    /// Current prefetch-queue fill per slot (telemetry; also the hook
    /// deterministic tests use to wait for producer quiescence — with a
    /// single producer, every queue at full depth implies nothing is
    /// in flight).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shared.slots.lock().unwrap().iter().map(|q| q.ready.len()).collect()
    }

    pub fn stats(&self) -> PipelineStats {
        PipelineStats {
            prefetched: self.shared.prefetched.get(),
            synchronous: self.shared.synchronous.get(),
            starved: self.shared.starved.get(),
            flushed: self.shared.flushed.get(),
            truncated_features: self.shared.truncated.get(),
            pool: self.shared.pool.stats(),
        }
    }
}

impl Drop for DataPlane {
    fn drop(&mut self) {
        // The store must happen under the slots mutex: a producer that has
        // checked `shutdown` but not yet parked holds that mutex, so
        // serializing on it guarantees every producer either sees the flag
        // or is already inside `wait` when the notify lands (no lost
        // wakeup, no hung join).
        {
            let _slots = self.shared.slots.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Relaxed);
        }
        self.shared.work.notify_all();
        for h in self.producers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Producer loop: claim the least-filled configured queue, assemble one
/// full batch for it outside the locks, deliver (or abandon if the slot
/// was reconfigured mid-assembly).
fn producer_main(shared: Arc<Shared>, _id: usize) {
    loop {
        // ---- claim a slot needing work ------------------------------------
        let claim = {
            let mut slots = shared.slots.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                let mut best: Option<(usize, usize, usize)> = None; // (fill, slot, bucket)
                for (i, q) in slots.iter().enumerate() {
                    if q.bucket == 0 {
                        continue;
                    }
                    let fill = q.ready.len() + q.pending;
                    if fill < shared.depth && best.map(|(f, _, _)| fill < f).unwrap_or(true) {
                        best = Some((fill, i, q.bucket));
                    }
                }
                match best {
                    Some((_, slot, bucket)) => {
                        slots[slot].pending += 1;
                        break (slot, bucket);
                    }
                    None => {
                        slots = shared.work.wait(slots).unwrap();
                    }
                }
            }
        };
        let (slot, bucket) = claim;

        // ---- assemble outside the slot lock --------------------------------
        let (batch, runs) = shared.assemble(bucket, bucket);

        // ---- deliver (or abandon on reconfigure/shutdown) ------------------
        let undelivered = {
            let mut slots = shared.slots.lock().unwrap();
            match slots.get_mut(slot) {
                Some(q) => {
                    q.pending = q.pending.saturating_sub(1);
                    if q.bucket == bucket && !shared.shutdown.load(Ordering::Relaxed) {
                        q.ready.push_back((batch, runs));
                        None
                    } else {
                        Some((batch, runs))
                    }
                }
                None => Some((batch, runs)),
            }
        };
        if let Some((batch, runs)) = undelivered {
            // Slot vanished or was re-bucketed mid-assembly: give the ids
            // back to the stream and the buffers to the pool.
            shared.abandon(batch, runs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};
    use crate::data::synthetic::Generator;

    fn dims() -> ModelDims {
        ModelDims { features: 256, hidden: 8, classes: 32, max_nnz: 16, max_labels: 4 }
    }

    fn sharded(n: usize) -> Arc<ShardedDataset> {
        let cfg = DataConfig { train_samples: n, avg_nnz: 6.0, ..Default::default() };
        let ds = Generator::new(&dims(), &cfg).generate(n, 1);
        Arc::new(ShardedDataset::from_dataset(&ds, 64))
    }

    #[test]
    fn sync_plane_batches_match_batcher_semantics() {
        let data = sharded(120);
        let dims = dims();
        let plane = DataPlane::new_sync(data.clone(), &dims, CompositionPolicy::Shuffled, 1);
        let b = plane.next_batch_for(0, 32, 20);
        assert_eq!(b.bucket, 32);
        assert_eq!(b.valid, 20);
        assert_eq!(b.sample_ids.len(), 20);
        assert_eq!(b.smask.iter().filter(|&&m| m == 1.0).count(), 20);
        b.shape_checks(&dims);
        let expected: usize =
            b.sample_ids.iter().map(|&id| data.nnz(id as usize).min(dims.max_nnz)).sum();
        assert_eq!(b.nnz, expected);
        assert_eq!(plane.stats().synchronous, 1);
        assert_eq!(plane.stats().prefetched, 0);
        assert!(!plane.is_async());
    }

    #[test]
    fn recycled_buffers_are_reused() {
        let data = sharded(100);
        let plane = DataPlane::new_sync(data, &dims(), CompositionPolicy::Shuffled, 2);
        let b = plane.next_batch_for(0, 16, 16);
        plane.recycle(b);
        let _b2 = plane.next_batch_for(0, 16, 16);
        let s = plane.stats();
        assert_eq!(s.pool.hits, 1, "second batch must recycle the first's buffers");
        assert_eq!(s.pool.misses, 1);
    }

    /// Spin until every queue holds `depth` batches. With one producer,
    /// full queues imply no assembly in flight, so the stream's emission
    /// count is exactly `consumed + queued`.
    fn wait_full(plane: &DataPlane, slots: usize, depth: usize) {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let d = plane.queue_depths();
            if d.len() == slots && d.iter().all(|&n| n == depth) {
                return;
            }
            assert!(std::time::Instant::now() < deadline, "producer never filled: {d:?}");
            std::thread::yield_now();
        }
    }

    #[test]
    fn async_plane_prefetches_full_batches() {
        let data = sharded(128);
        let pcfg = PipelineConfig {
            queue_depth: 2,
            producer_threads: 1,
            policy: CompositionPolicy::Shuffled,
            shard_samples: 64,
        };
        let plane = DataPlane::new(data, &dims(), &pcfg, 1, 3);
        assert!(plane.is_async());
        plane.begin_window(&[16, 16]);
        wait_full(&plane, 2, 2);
        let b = plane.next_batch_for(0, 16, 16);
        assert_eq!(b.valid, 16);
        plane.recycle(b);
        let s = plane.stats();
        assert_eq!(s.prefetched, 1, "a full queue must serve the pop");
        assert_eq!(s.starved, 0);
    }

    #[test]
    fn flush_ungets_and_the_epoch_is_conserved() {
        // One producer, two 16-slots over a 128-sample corpus. Consume 4
        // batches, let the queues refill to 2+2, then flush everything by
        // going idle: emissions are exactly 64 consumed + 64 queued = one
        // whole epoch, the flush ungets the queued 64, and a synchronous
        // drain must re-serve exactly those 64 — every id once per epoch
        // despite crossing producers, queues, and a flush.
        let data = sharded(128);
        let pcfg = PipelineConfig {
            queue_depth: 2,
            producer_threads: 1,
            policy: CompositionPolicy::Shuffled,
            shard_samples: 64,
        };
        let plane = DataPlane::new(data, &dims(), &pcfg, 1, 5);
        plane.begin_window(&[16, 16]);
        let mut counts = std::collections::HashMap::new();
        for i in 0..4 {
            let b = plane.next_batch_for(i % 2, 16, 16);
            for &id in &b.sample_ids {
                *counts.entry(id).or_insert(0u32) += 1;
            }
            plane.recycle(b);
        }
        wait_full(&plane, 2, 2);
        plane.begin_window(&[]); // idle: flush both queues, producer parks
        assert_eq!(plane.stats().flushed, 4, "both queues flushed");
        for _ in 0..4 {
            let b = plane.next_batch(16, 16);
            for &id in &b.sample_ids {
                *counts.entry(id).or_insert(0) += 1;
            }
            plane.recycle(b);
        }
        assert_eq!(counts.len(), 128, "flush + unget must not lose samples");
        assert!(counts.values().all(|&c| c == 1), "epoch served exactly once despite the flush");
    }

    #[test]
    fn rebucketing_flushes_the_old_shape() {
        let data = sharded(128);
        let pcfg = PipelineConfig {
            queue_depth: 2,
            producer_threads: 1,
            policy: CompositionPolicy::Shuffled,
            shard_samples: 64,
        };
        let plane = DataPlane::new(data, &dims(), &pcfg, 1, 7);
        plane.begin_window(&[16]);
        wait_full(&plane, 1, 2);
        plane.begin_window(&[32]);
        assert_eq!(plane.stats().flushed, 2, "old-bucket batches flushed");
        let b = plane.next_batch_for(0, 32, 32);
        assert_eq!(b.bucket, 32, "post-reconfigure batches use the new bucket");
        plane.recycle(b);
    }

    #[test]
    fn partial_batches_fall_back_to_sync_assembly() {
        let data = sharded(64);
        let pcfg = PipelineConfig {
            queue_depth: 2,
            producer_threads: 1,
            policy: CompositionPolicy::Shuffled,
            shard_samples: 64,
        };
        let plane = DataPlane::new(data, &dims(), &pcfg, 1, 7);
        plane.begin_window(&[16]);
        let b = plane.next_batch_for(0, 16, 5);
        assert_eq!(b.valid, 5);
        assert!(plane.stats().synchronous >= 1);
    }

    #[test]
    fn nnz_estimate_reads_the_manifest() {
        let data = sharded(200);
        let plane = DataPlane::new_sync(data.clone(), &dims(), CompositionPolicy::Shuffled, 9);
        let est = plane.nnz_estimate();
        assert!(est > 0.0);
        assert!((est - data.mean_nnz_clamped(16)).abs() < 1e-12);
    }

    #[test]
    fn pipeline_counters_land_in_the_obs_registry() {
        let obs = ObsHandle::disabled(); // registry counts even when tracing is off
        let pcfg =
            PipelineConfig { policy: CompositionPolicy::Shuffled, ..PipelineConfig::default() };
        let plane = DataPlane::new_obs(sharded(64), &dims(), &pcfg, 0, 1, &obs);
        let b = plane.next_batch(16, 16);
        plane.recycle(b);
        let rows = obs.registry().snapshot();
        let sync = rows.iter().find(|r| r.name == "data.synchronous").unwrap();
        assert_eq!(sync.kind, "counter");
        assert_eq!(sync.value, 1.0);
        assert_eq!(plane.stats().synchronous, 1, "stats() reads the same atomics");
        assert!(rows.iter().any(|r| r.name == "data.starved"));
    }

    #[test]
    fn shutdown_joins_producers_cleanly() {
        let data = sharded(64);
        let pcfg = PipelineConfig {
            queue_depth: 4,
            producer_threads: 3,
            policy: CompositionPolicy::NnzBalanced,
            shard_samples: 32,
        };
        let plane = DataPlane::new(data, &dims(), &pcfg, 3, 11);
        plane.begin_window(&[16, 32, 16]);
        let b = plane.next_batch_for(1, 32, 32);
        plane.recycle(b);
        drop(plane); // must not hang or panic
    }
}

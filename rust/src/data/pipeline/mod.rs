//! The sparse data plane: sharded ingestion, async prefetch, and nnz-aware
//! batch composition (the producer/consumer layer between datasets and the
//! coordinator).
//!
//! * [`shard`] — [`ShardedDataset`]: the corpus as bounded CSR shards,
//!   each with an nnz-histogram manifest; loadable shard-by-shard from
//!   libSVM files instead of whole-corpus.
//! * [`buffer_pool`] — [`BufferPool`]: recycles
//!   [`PaddedBatch`](crate::data::PaddedBatch) allocations so the hot path
//!   stops re-`vec!`-ing four buffers per batch.
//! * [`compose`] — [`SampleStream`]: epoch-exact sample-id emission under a
//!   [`CompositionPolicy`](crate::config::CompositionPolicy) (`Shuffled` /
//!   `NnzBalanced` / `NnzSorted`).
//! * [`plane`] — [`DataPlane`]: bounded per-device prefetch queues filled
//!   by background producers (threaded engine) or synchronous assembly
//!   (deterministic virtual-time engine), with starvation / flush /
//!   truncation counters feeding metrics.
//!
//! The paper's core observation is that per-batch nnz variance is what
//! destabilizes heterogeneous training; this subsystem makes batch *cost*
//! a controlled quantity instead of a measured afterthought.

pub mod buffer_pool;
pub mod compose;
pub mod plane;
pub mod shard;

pub use buffer_pool::{BufferPool, PoolStats};
pub use compose::SampleStream;
pub use plane::{DataPlane, PipelineStats};
pub use shard::{ShardMeta, ShardedDataset, NNZ_HIST_BUCKETS};

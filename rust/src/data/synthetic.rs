//! Synthetic XML dataset generator — the Table 1 substitute.
//!
//! Amazon-670k / Delicious-200k are not available offline, so we generate
//! corpora with the same *shape statistics* (DESIGN.md §2): Zipf feature
//! popularity, log-normal nnz per sample, Zipf label popularity, and —
//! crucially — a learnable generative structure: every class owns a small
//! set of "characteristic" features, and a sample's features are a noisy
//! mixture of its labels' characteristic features plus background. P@1 on
//! held-out data is therefore meaningfully improvable by training, which is
//! what the paper's accuracy curves require.

use crate::config::{DataConfig, ModelDims};
use crate::util::rng::{Rng, Zipf};

use super::sparse::{DatasetBuilder, SparseDataset};

/// Characteristic features per class.
const CLASS_FEATS: usize = 6;
/// Probability that a feature slot is drawn from a label's characteristic
/// set rather than from the background Zipf.
const SIGNAL_P: f64 = 0.7;

/// Generator with frozen class structure — train and test splits come from
/// the same instance so they share the signal.
pub struct Generator {
    dims: ModelDims,
    cfg: DataConfig,
    class_feats: Vec<[u32; CLASS_FEATS]>,
    feat_zipf: Zipf,
    label_zipf: Zipf,
}

impl Generator {
    pub fn new(dims: &ModelDims, cfg: &DataConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let feat_zipf = Zipf::new(dims.features, cfg.zipf_s);
        let label_zipf = Zipf::new(dims.classes, cfg.zipf_s);
        // Freeze each class's characteristic features (drawn from the same
        // popularity law so "head" classes share head features, like real
        // text corpora).
        let class_feats = (0..dims.classes)
            .map(|_| {
                let mut feats = [0u32; CLASS_FEATS];
                for f in feats.iter_mut() {
                    *f = feat_zipf.sample(&mut rng) as u32;
                }
                feats
            })
            .collect();
        Generator { dims: dims.clone(), cfg: cfg.clone(), class_feats, feat_zipf, label_zipf }
    }

    /// Generate `n` samples with the given split seed.
    pub fn generate(&self, n: usize, split_seed: u64) -> SparseDataset {
        let mut rng = Rng::new(self.cfg.seed ^ split_seed.wrapping_mul(0x9E3779B97F4A7C15));
        let mut b = DatasetBuilder::new(self.dims.features, self.dims.classes);
        let mut idx_buf: Vec<u32> = Vec::new();
        let mut val_buf: Vec<f32> = Vec::new();
        let mut lab_buf: Vec<u32> = Vec::new();
        for _ in 0..n {
            self.sample_into(&mut rng, &mut idx_buf, &mut val_buf, &mut lab_buf);
            b.push(&idx_buf, &val_buf, &lab_buf).expect("generator produced invalid sample");
        }
        let ds = b.finish();
        debug_assert!(ds.check().is_ok());
        ds
    }

    fn sample_into(
        &self,
        rng: &mut Rng,
        idx_buf: &mut Vec<u32>,
        val_buf: &mut Vec<f32>,
        lab_buf: &mut Vec<u32>,
    ) {
        idx_buf.clear();
        val_buf.clear();
        lab_buf.clear();

        // --- labels: 1 + Poisson-ish count, Zipf-popular classes ---------
        let target_labels =
            sample_count(rng, self.cfg.avg_labels, 1, self.dims.max_labels);
        let mut seen = [false; 0]; // placeholder to keep clippy quiet
        let _ = &mut seen;
        while lab_buf.len() < target_labels {
            let l = self.label_zipf.sample(rng) as u32;
            if !lab_buf.contains(&l) {
                lab_buf.push(l);
            }
        }

        // --- features: log-normal nnz, signal + background mixture -------
        let nnz = sample_nnz(rng, self.cfg.avg_nnz, self.cfg.nnz_sigma, self.dims.max_nnz);
        while idx_buf.len() < nnz {
            let f = if rng.f64() < SIGNAL_P {
                // Characteristic feature of a random one of this sample's labels.
                let l = lab_buf[rng.range(0, lab_buf.len())] as usize;
                let feats = &self.class_feats[l];
                feats[rng.range(0, CLASS_FEATS)]
            } else {
                self.feat_zipf.sample(rng) as u32
            };
            if !idx_buf.contains(&f) {
                idx_buf.push(f);
                // tf-idf-like positive weight.
                val_buf.push(rng.lognormal(0.0, 0.4) as f32);
            }
        }
    }
}

/// Clamp a log-normal draw with mean ≈ `avg` into [1, max].
fn sample_nnz(rng: &mut Rng, avg: f64, sigma: f64, max: usize) -> usize {
    // For lognormal, E[X] = exp(mu + sigma^2/2) => mu = ln(avg) - sigma^2/2.
    let mu = avg.ln() - sigma * sigma / 2.0;
    let draw = rng.lognormal(mu, sigma).round() as i64;
    draw.clamp(1, max as i64) as usize
}

/// Geometric-flavoured label count with mean ≈ `avg`, in [min, max].
fn sample_count(rng: &mut Rng, avg: f64, min: usize, max: usize) -> usize {
    if avg <= min as f64 {
        return min;
    }
    // 1 + Binomial-ish accumulation: add labels with prob p until max.
    let extra_mean = avg - min as f64;
    let p = extra_mean / (extra_mean + 1.0);
    let mut n = min;
    while n < max && rng.f64() < p {
        n += 1;
    }
    n
}

/// Convenience: build train + test splits.
pub fn train_test(dims: &ModelDims, cfg: &DataConfig) -> (SparseDataset, SparseDataset) {
    let gen = Generator::new(dims, cfg);
    let train = gen.generate(cfg.train_samples, 1);
    let test = gen.generate(cfg.test_samples, 2);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelDims};

    fn small_dims() -> ModelDims {
        ModelDims { features: 512, hidden: 16, classes: 64, max_nnz: 24, max_labels: 6 }
    }

    #[test]
    fn statistics_match_targets() {
        let dims = small_dims();
        let cfg = DataConfig { train_samples: 4000, avg_nnz: 10.0, avg_labels: 2.0, ..Default::default() };
        let gen = Generator::new(&dims, &cfg);
        let ds = gen.generate(4000, 1);
        ds.check().unwrap();
        assert_eq!(ds.len(), 4000);
        // Table-1-style shape statistics within tolerance.
        assert!((ds.avg_nnz() - 10.0).abs() < 1.5, "avg_nnz={}", ds.avg_nnz());
        assert!((ds.avg_labels() - 2.0).abs() < 0.6, "avg_labels={}", ds.avg_labels());
        assert!(ds.max_nnz() <= dims.max_nnz);
        assert!(ds.max_labels() <= dims.max_labels);
    }

    #[test]
    fn deterministic_given_seed() {
        let dims = small_dims();
        let cfg = DataConfig { train_samples: 50, ..Default::default() };
        let a = Generator::new(&dims, &cfg).generate(50, 1);
        let b = Generator::new(&dims, &cfg).generate(50, 1);
        for i in 0..50 {
            assert_eq!(a.sample(i).indices, b.sample(i).indices);
            assert_eq!(a.sample(i).labels, b.sample(i).labels);
        }
    }

    #[test]
    fn splits_differ_but_share_structure() {
        let dims = small_dims();
        let cfg = DataConfig { ..Default::default() };
        let gen = Generator::new(&dims, &cfg);
        let train = gen.generate(100, 1);
        let test = gen.generate(100, 2);
        // Different draws…
        assert_ne!(train.sample(0).indices, test.sample(0).indices);
        // …but same generative structure (checked statistically elsewhere).
        assert_eq!(train.num_features, test.num_features);
    }

    #[test]
    fn feature_popularity_is_skewed() {
        let dims = small_dims();
        let cfg = DataConfig { train_samples: 2000, ..Default::default() };
        let ds = Generator::new(&dims, &cfg).generate(2000, 1);
        let mut counts = vec![0usize; dims.features];
        for i in 0..ds.len() {
            for &f in ds.sample(i).indices {
                counts[f as usize] += 1;
            }
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[counts.len() / 2..].iter().sum();
        assert!(head > tail, "power-law head should dominate: head={head} tail={tail}");
    }

    #[test]
    fn signal_exists_features_predict_labels() {
        // A sample's features should overlap its labels' characteristic
        // features far more often than chance.
        let dims = small_dims();
        let cfg = DataConfig { ..Default::default() };
        let gen = Generator::new(&dims, &cfg);
        let ds = gen.generate(300, 1);
        let mut hit = 0usize;
        let mut total = 0usize;
        for i in 0..ds.len() {
            let s = ds.sample(i);
            for &f in s.indices {
                total += 1;
                if s.labels.iter().any(|&l| gen.class_feats[l as usize].contains(&f)) {
                    hit += 1;
                }
            }
        }
        let frac = hit as f64 / total as f64;
        assert!(frac > 0.4, "signal fraction too low: {frac}");
    }
}

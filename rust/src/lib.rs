//! # heterosparse
//!
//! A production-shaped reproduction of *Adaptive Elastic Training for Sparse
//! Deep Learning on Heterogeneous Multi-GPU Servers* (Ma, Rusu, Wu, Sim —
//! CS.DC 2021) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the HeteroGPU-style coordinator: an elastic
//!   device pool (runtime join/leave, straggler quarantine, scripted
//!   elasticity traces), dynamic scheduler, GPU-manager workers, adaptive
//!   batch-size scaling (Algorithm 1), normalized model merging with
//!   perturbation and momentum over the active device subset (Algorithm 2),
//!   the Elastic/Synchronous/CROSSBOW baselines, a SLIDE CPU baseline, a
//!   multi-stream all-reduce simulation, an online serving plane
//!   (snapshot registry + micro-batch inference) closing the train→serve
//!   loop, a multi-tenant fleet scheduler (device leases, weighted
//!   fair share, SLO-aware priority preemption) co-scheduling many
//!   training jobs and serve lanes on one shared fleet, and an online
//!   cost-model calibration plane ([`tuning`]) that estimates per-device
//!   costs from live timings and feeds dispatch, batch scaling, fleet
//!   fair share, and serve routing — so scheduling follows measured
//!   speeds, not config constants, even as devices throttle and recover —
//!   and a cluster scale-out plane ([`cluster`]) running many such
//!   servers over a simulated inter-server fabric with two-tier
//!   staleness-weighted merging, link-calibrated adaptive sync cadence,
//!   cross-server straggler demotion, and correlated rack failures.
//! * **Layer 2** — a JAX 3-layer sparse MLP (`python/compile/model.py`),
//!   AOT-lowered to HLO text per batch-size bucket.
//! * **Layer 1** — Pallas kernels for the sparse gather-SpMM input layer and
//!   the tiled online-softmax (`python/compile/kernels/`).
//!
//! Python never runs on the training path: `make artifacts` lowers the model
//! once; this crate loads `artifacts/*.hlo.txt` through the PJRT C API
//! (`xla` crate) and owns everything else.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod allreduce;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod fleet;
pub mod harness;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod slide;
pub mod tuning;
pub mod util;

/// Crate-wide result type (anyhow-based, matching the `xla` crate style).
pub type Result<T> = anyhow::Result<T>;

//! Integration over the elastic device pool (hermetic, reference backend):
//! scripted membership traces, straggler quarantine, merge-weight
//! renormalization over the active subset, and parity with static runs.

use heterosparse::config::{Config, DataConfig, DeviceConfig, ExecMode, ModelDims, SgdConfig, Strategy};
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::harness::{run_single, Backend};
use heterosparse::metrics::RunLog;

fn small_cfg(strategy: Strategy, mode: ExecMode) -> Config {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 32,
        beta: 4,
        lr_bmax: 0.4,
        mega_batches: 24,
        num_mega_batches: 8,
        initial_batch: 32,
        warmup_mega_batches: 0,
        seed: 3,
        ..Default::default()
    };
    cfg.devices = DeviceConfig {
        count: 4,
        speed_factors: vec![1.0, 1.1, 1.21, 1.32],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 11,
    };
    cfg.data = DataConfig { train_samples: 2_000, test_samples: 400, avg_nnz: 6.0, ..Default::default() };
    cfg.runtime.mode = mode;
    cfg.strategy.kind = strategy;
    cfg.validate().unwrap();
    cfg
}

fn run(cfg: &Config) -> RunLog {
    run_single(cfg, Backend::Reference, TrainerOptions::default()).unwrap()
}

/// The acceptance scenario: remove 1 of 4 devices at mega-batch N, re-add
/// at M. The run completes, the RunLog pool events show the device-count
/// transitions, merge weights renormalize over the active subset at every
/// merge, and the final P@1 lands within tolerance of the static-pool run.
#[test]
fn scripted_trace_completes_and_matches_static_run() {
    let static_cfg = small_cfg(Strategy::Adaptive, ExecMode::Virtual);
    let static_log = run(&static_cfg);

    let mut cfg = small_cfg(Strategy::Adaptive, ExecMode::Virtual);
    cfg.elastic.events = vec!["at_mb=2 remove=1".to_string(), "at_mb=5 add=1".to_string()];
    cfg.validate().unwrap();
    let log = run(&cfg);

    // Device-count transitions 4 -> 3 -> 4 at the scripted boundaries.
    assert_eq!(log.device_counts(), vec![4, 4, 3, 3, 3, 4, 4, 4]);
    assert_eq!(log.pool_events.len(), 2);
    assert_eq!(log.pool_events[0].action, "remove");
    assert_eq!(log.pool_events[0].mega_batch, 2);
    // remove=1 takes the slowest device (highest speed factor = id 3).
    assert_eq!(log.pool_events[0].device, 3);
    assert_eq!(log.pool_events[1].action, "add");
    assert_eq!(log.pool_events[1].mega_batch, 5);

    // Merge weights renormalize over the active subset at every merge:
    // inactive devices carry exactly zero weight and the active weights sum
    // to 1 (perturbation may denormalize by at most ±delta).
    for r in &log.rows {
        let sum: f64 = r.merge_weights.iter().sum();
        assert!(
            (sum - 1.0).abs() <= cfg.merge.delta + 1e-9,
            "mb {}: weight sum {sum}",
            r.mega_batch
        );
        for d in 0..4 {
            let active = r.active_devices.contains(&d);
            assert_eq!(
                r.merge_weights[d] == 0.0 && r.updates[d] == 0,
                !active,
                "mb {}: device {d} active={active} weight={} updates={}",
                r.mega_batch,
                r.merge_weights[d],
                r.updates[d]
            );
        }
    }

    // Both runs complete all mega-batches and learn comparably.
    assert_eq!(log.rows.len(), static_log.rows.len());
    let p_elastic = log.best_accuracy();
    let p_static = static_log.best_accuracy();
    assert!(p_elastic > 0.15, "elastic run failed to learn: {p_elastic}");
    assert!(
        (p_elastic - p_static).abs() < 0.15,
        "elastic P@1 {p_elastic} too far from static {p_static}"
    );
}

/// Losing devices must make the (virtual) clock slower per mega-batch, not
/// corrupt the run: the 3-device stretch processes the same sample budget
/// over fewer devices.
#[test]
fn shrunken_pool_still_conserves_sample_budget() {
    let mut cfg = small_cfg(Strategy::Adaptive, ExecMode::Virtual);
    cfg.elastic.events = vec!["at_mb=1 remove=2".to_string()];
    cfg.validate().unwrap();
    let log = run(&cfg);
    let budget = cfg.sgd.mega_batch_samples() as u64;
    for r in &log.rows {
        let processed: u64 = r.updates.iter().sum();
        assert!(processed > 0);
        // Dynamic dispatch conserves the budget exactly regardless of pool
        // size: cumulative samples grow by exactly one budget per mega-batch.
        assert_eq!(r.samples, budget * (r.mega_batch as u64 + 1));
    }
}

/// The straggler policy quarantines a pathologically slow device and
/// auto-readmits it after the configured number of mega-batches — all
/// visible in the pool-event log.
#[test]
fn straggler_is_quarantined_and_readmitted() {
    let mut cfg = small_cfg(Strategy::Adaptive, ExecMode::Virtual);
    // Device 3 runs 4x slower than the rest; quarantine at 2x the median.
    cfg.devices.speed_factors = vec![1.0, 1.0, 1.0, 4.0];
    cfg.elastic.straggler_factor = 2.0;
    cfg.elastic.straggler_window = 2;
    cfg.elastic.quarantine_mega_batches = 3;
    cfg.validate().unwrap();
    let log = run(&cfg);

    let quarantines: Vec<_> =
        log.pool_events.iter().filter(|e| e.action == "quarantine").collect();
    assert!(!quarantines.is_empty(), "straggler never quarantined: {:?}", log.pool_events);
    assert_eq!(quarantines[0].device, 3);
    assert!(quarantines[0].reason.contains("median"));
    // The first quarantine needs a full 2-mega-batch window first.
    assert!(quarantines[0].mega_batch >= 2);
    let readmits: Vec<_> = log.pool_events.iter().filter(|e| e.action == "readmit").collect();
    assert!(!readmits.is_empty(), "quarantined device never readmitted");
    assert_eq!(readmits[0].device, 3);
    assert_eq!(readmits[0].mega_batch, quarantines[0].mega_batch + 3);
    // While quarantined the pool runs on 3 devices.
    let counts = log.device_counts();
    assert!(counts.contains(&3), "pool never shrank: {counts:?}");
}

/// The elastic pool works identically through the threaded engine: workers
/// for removed devices park, the hot-re-added device's worker resumes.
#[test]
fn threaded_engine_rides_through_pool_events() {
    let mut cfg = small_cfg(Strategy::Adaptive, ExecMode::Real);
    cfg.sgd.num_mega_batches = 5;
    cfg.data.train_samples = 800;
    cfg.data.test_samples = 200;
    cfg.elastic.events = vec!["at_mb=1 remove_id=1".to_string(), "at_mb=3 add_id=1".to_string()];
    cfg.validate().unwrap();
    let log = run(&cfg);
    assert_eq!(log.device_counts(), vec![4, 3, 3, 4, 4]);
    for r in &log.rows {
        assert!(r.loss.is_finite());
        let active_updates: u64 =
            r.active_devices.iter().map(|&d| r.updates[d]).sum();
        assert!(active_updates > 0);
        if !r.active_devices.contains(&1) {
            assert_eq!(r.updates[1], 0, "parked worker did work at mb {}", r.mega_batch);
        }
    }
}

/// Hot-add spares: a device that was never part of the initial fleet joins
/// mid-run and picks up the current global model.
#[test]
fn spare_device_hot_adds_mid_run() {
    let mut cfg = small_cfg(Strategy::Adaptive, ExecMode::Virtual);
    cfg.devices.count = 2;
    cfg.devices.speed_factors = vec![1.0, 1.2];
    cfg.elastic.spare_devices = vec![1.05];
    cfg.elastic.events = vec!["at_mb=3 add=1".to_string()];
    cfg.validate().unwrap();
    let log = run(&cfg);
    assert_eq!(log.device_counts(), vec![2, 2, 2, 3, 3, 3, 3, 3]);
    let adds: Vec<_> = log.pool_events.iter().filter(|e| e.action == "add").collect();
    assert_eq!(adds.len(), 1);
    assert_eq!(adds[0].device, 2, "the spare has the first post-fleet id");
    // Once in, the spare does real work and carries merge weight.
    let last = log.rows.last().unwrap();
    assert!(last.updates[2] > 0);
    assert!(last.merge_weights[2] > 0.0);
    assert!(log.best_accuracy() > 0.1, "P@1 {}", log.best_accuracy());
}

/// Elastic strategy (static equal batches) also renormalizes its uniform
/// merge over the active subset: 1/3 weights while a device is out.
#[test]
fn elastic_strategy_uniform_weights_track_pool_size() {
    let mut cfg = small_cfg(Strategy::Elastic, ExecMode::Virtual);
    cfg.elastic.events = vec!["at_mb=2 remove=1".to_string()];
    cfg.validate().unwrap();
    let log = run(&cfg);
    for r in &log.rows {
        let g = r.active_devices.len() as f64;
        for &d in &r.active_devices {
            assert!(
                (r.merge_weights[d] - 1.0 / g).abs() < 1e-12,
                "mb {}: weight {} != 1/{g}",
                r.mega_batch,
                r.merge_weights[d]
            );
        }
    }
}

//! Integration + property tests for the sparse data plane: epoch-exact
//! sample conservation across shards/queues/policies, buffer-pool
//! cleanliness, the NnzBalanced dispersion guarantee, and the end-to-end
//! threaded-engine prefetch path.

use std::sync::Arc;

use heterosparse::config::{
    CompositionPolicy, Config, DataConfig, ExecMode, ModelDims, PipelineConfig, Strategy,
};
use heterosparse::data::pipeline::{BufferPool, DataPlane, ShardedDataset};
use heterosparse::data::synthetic::Generator;
use heterosparse::harness::{run_single, Backend};
use heterosparse::util::prop;

fn dims() -> ModelDims {
    ModelDims { features: 512, hidden: 8, classes: 32, max_nnz: 48, max_labels: 4 }
}

/// Heavy-tailed corpus: log-normal nnz with sigma 1.2 spans ~1..48.
fn heavy_tailed(n: usize, shard_samples: usize) -> Arc<ShardedDataset> {
    let cfg = DataConfig { train_samples: n, avg_nnz: 10.0, nnz_sigma: 1.2, ..Default::default() };
    let ds = Generator::new(&dims(), &cfg).generate(n, 1);
    Arc::new(ShardedDataset::from_dataset(&ds, shard_samples))
}

/// Property (satellite + acceptance): under EVERY composition policy, with
/// random batch-size sequences and small shards, one epoch through the
/// data plane serves each sample id exactly once.
#[test]
fn prop_every_policy_conserves_the_epoch() {
    let n = 240usize;
    let data = heavy_tailed(n, 64); // 4 shards, last partial
    for policy in CompositionPolicy::all() {
        let gen = prop::VecU64 { min_len: 1, max_len: 10, item_lo: 1, item_hi: 50 };
        prop::check(25, 0xB00C ^ policy as u64, gen, |sizes| {
            let plane = DataPlane::new_sync(data.clone(), &dims(), policy, sizes.iter().sum());
            let mut seen = std::collections::HashSet::new();
            let mut drawn = 0usize;
            // Random batch sizes until the epoch would wrap, then top the
            // epoch off exactly.
            for &s in sizes {
                let s = s as usize;
                if drawn + s > n {
                    break;
                }
                let b = plane.next_batch_for(0, s, s);
                drawn += s;
                for &id in &b.sample_ids {
                    if !seen.insert(id) {
                        return Err(format!("{policy:?}: sample {id} served twice in one epoch"));
                    }
                }
                plane.recycle(b);
            }
            while drawn < n {
                let s = (n - drawn).min(32);
                let b = plane.next_batch_for(0, s.max(1), s.max(1));
                drawn += s;
                for &id in &b.sample_ids {
                    if !seen.insert(id) {
                        return Err(format!("{policy:?}: sample {id} served twice in one epoch"));
                    }
                }
                plane.recycle(b);
            }
            if seen.len() != n {
                return Err(format!("{policy:?}: epoch covered {} of {n} samples", seen.len()));
            }
            Ok(())
        });
    }
}

/// Property (satellite): the buffer pool never hands out a stale batch —
/// whatever shapes were used and returned before, every `get` is
/// indistinguishable from a fresh allocation.
#[test]
fn prop_buffer_pool_never_returns_stale_state() {
    let data = heavy_tailed(200, 64);
    let d = dims();
    let gen = prop::VecU64 { min_len: 1, max_len: 16, item_lo: 1, item_hi: 40 };
    prop::check(40, 0xCAFE, gen, |sizes| {
        let pool = BufferPool::new(4);
        let plane = DataPlane::new_sync(data.clone(), &d, CompositionPolicy::Shuffled, 99);
        for &s in sizes {
            let bucket = s as usize;
            // Dirty a batch with real samples, recycle it, then check the
            // next lease is clean.
            let dirty = plane.next_batch_for(0, bucket, bucket);
            pool.put(dirty);
            let b = pool.get(bucket + 1, d.max_nnz, d.max_labels);
            if b.valid != 0 || b.nnz != 0 || !b.sample_ids.is_empty() {
                return Err(format!("stale scalar state at bucket {bucket}"));
            }
            if b.idx.len() != (bucket + 1) * d.max_nnz || b.smask.len() != bucket + 1 {
                return Err(format!("wrong shape at bucket {bucket}"));
            }
            if b.idx.iter().any(|&v| v != 0)
                || b.val.iter().any(|&v| v != 0.0)
                || b.lab.iter().any(|&v| v != 0)
                || b.lab_w.iter().any(|&v| v != 0.0)
                || b.smask.iter().any(|&v| v != 0.0)
            {
                return Err(format!("stale buffer contents at bucket {bucket}"));
            }
            pool.put(b);
        }
        Ok(())
    });
}

/// Acceptance criterion: on a synthetic heavy-tailed corpus, NnzBalanced
/// demonstrably reduces the per-batch nnz coefficient of variation vs
/// Shuffled (and NnzSorted demonstrably inflates it).
#[test]
fn nnz_balanced_cuts_per_batch_cost_dispersion() {
    let data = heavy_tailed(2048, 256);
    let d = dims();
    let cv = |policy: CompositionPolicy| {
        let plane = DataPlane::new_sync(data.clone(), &d, policy, 17);
        let nnzs: Vec<f64> = (0..32)
            .map(|_| {
                let b = plane.next_batch_for(0, 64, 64);
                let nnz = b.nnz as f64;
                plane.recycle(b);
                nnz
            })
            .collect();
        let mean = nnzs.iter().sum::<f64>() / nnzs.len() as f64;
        let var = nnzs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / nnzs.len() as f64;
        var.sqrt() / mean
    };
    let shuffled = cv(CompositionPolicy::Shuffled);
    let balanced = cv(CompositionPolicy::NnzBalanced);
    let sorted = cv(CompositionPolicy::NnzSorted);
    assert!(
        balanced < shuffled * 0.5,
        "NnzBalanced CV {balanced:.4} must be well below Shuffled {shuffled:.4}"
    );
    assert!(
        sorted > shuffled * 2.0,
        "NnzSorted is the stress policy: CV {sorted:.4} vs Shuffled {shuffled:.4}"
    );
}

/// End to end: a threaded-engine (Real mode) run trains through the async
/// data plane — prefetch engages, buffers recycle, and the run still
/// learns. This is the production shape of the whole PR.
#[test]
fn threaded_run_trains_through_the_async_plane() {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd.b_min = 8;
    cfg.sgd.b_max = 32;
    cfg.sgd.beta = 4;
    cfg.sgd.initial_batch = 32;
    cfg.sgd.lr_bmax = 0.4;
    cfg.sgd.mega_batches = 8;
    cfg.sgd.num_mega_batches = 4;
    cfg.devices.count = 2;
    cfg.devices.speed_factors = vec![1.0, 1.25];
    cfg.data =
        DataConfig { train_samples: 1200, test_samples: 200, avg_nnz: 6.0, ..Default::default() };
    cfg.data.pipeline = PipelineConfig {
        queue_depth: 2,
        producer_threads: 2,
        policy: CompositionPolicy::NnzBalanced,
        shard_samples: 256,
    };
    cfg.runtime.mode = ExecMode::Real;
    cfg.strategy.kind = Strategy::Adaptive;
    // Pin batch sizes: stable buckets mean the queues filled during each
    // merge/eval gap survive into the next mega-batch, so the prefetch
    // path provably engages (no rescale-flush race in the assertion).
    cfg.strategy.batch_scaling = false;
    cfg.validate().unwrap();

    let log = run_single(&cfg, Backend::Reference, Default::default()).unwrap();
    assert_eq!(log.rows.len(), 4);
    let first = log.rows[0].loss;
    let last = log.rows.last().unwrap().loss;
    assert!(last < first + 0.05, "loss {first} -> {last}");

    let p = &log.rows.last().unwrap().pipeline;
    assert!(p.prefetched > 0, "async prefetch must have served batches: {p:?}");
    assert!(p.pool_hits > 0, "buffer recycling must have engaged: {p:?}");
    assert_eq!(p.truncated_features, 0, "max_nnz=12 fits the generator's cap");
}

/// Virtual mode stays deterministic through the plane: identical runs,
/// identical telemetry.
#[test]
fn virtual_mode_is_deterministic_through_the_plane() {
    let run = || {
        let mut cfg = Config::default();
        cfg.model =
            ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
        cfg.sgd.b_min = 8;
        cfg.sgd.b_max = 32;
        cfg.sgd.beta = 4;
        cfg.sgd.initial_batch = 32;
        cfg.sgd.mega_batches = 8;
        cfg.sgd.num_mega_batches = 3;
        cfg.devices.count = 2;
        cfg.devices.speed_factors = vec![1.0, 1.2];
        cfg.devices.jitter = 0.0;
        cfg.data = DataConfig {
            train_samples: 800,
            test_samples: 150,
            avg_nnz: 6.0,
            ..Default::default()
        };
        cfg.data.pipeline.policy = CompositionPolicy::NnzBalanced;
        cfg.validate().unwrap();
        run_single(&cfg, Backend::Reference, Default::default()).unwrap()
    };
    let a = run();
    let b = run();
    for (x, y) in a.rows.iter().zip(&b.rows) {
        assert_eq!(x.loss, y.loss);
        assert_eq!(x.clock, y.clock);
        assert_eq!(x.nnz_mean, y.nnz_mean);
        assert_eq!(x.nnz_cv, y.nnz_cv);
    }
}

/// Sharded libSVM ingestion feeds the plane identically to the in-memory
/// path.
#[test]
fn libsvm_sharded_ingestion_round_trips_through_the_plane() {
    let d = dims();
    let cfg = DataConfig { train_samples: 300, avg_nnz: 8.0, ..Default::default() };
    let ds = Generator::new(&d, &cfg).generate(300, 1);
    let dir = std::env::temp_dir().join("hs-pipeline-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("corpus.txt");
    heterosparse::data::libsvm::write(&path, &ds).unwrap();

    let sharded = ShardedDataset::from_libsvm(&path, 128).unwrap();
    assert_eq!(sharded.len(), 300);
    assert_eq!(sharded.num_shards(), 3);
    for i in 0..ds.len() {
        assert_eq!(sharded.sample(i).indices, ds.sample(i).indices);
    }
    let plane = DataPlane::new_sync(Arc::new(sharded), &d, CompositionPolicy::Shuffled, 21);
    let b = plane.next_batch_for(0, 32, 32);
    assert_eq!(b.valid, 32);
    assert!(b.nnz > 0);
}

/// Truncation surfacing (satellite): a model cap below the corpus' nnz
/// range drops feature tails — counted, not silent.
#[test]
fn truncation_is_surfaced_through_plane_stats() {
    let data = heavy_tailed(256, 128);
    let tight = ModelDims { max_nnz: 4, ..dims() };
    let plane = DataPlane::new_sync(data.clone(), &tight, CompositionPolicy::Shuffled, 23);
    let b = plane.next_batch_for(0, 64, 64);
    let expected: u64 =
        b.sample_ids.iter().map(|&id| data.nnz(id as usize).saturating_sub(4) as u64).sum();
    assert!(expected > 0, "heavy tail must overflow max_nnz=4");
    assert_eq!(plane.stats().truncated_features, expected);
    // And per-row nnz respects the cap.
    assert!(b.nnz <= 64 * 4);
}

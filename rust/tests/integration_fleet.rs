//! Fleet-scheduler integration: lease conservation under randomized
//! grant/revoke/churn (the property the whole subsystem rests on), plus
//! end-to-end co-scheduling — determinism, request conservation across
//! lease churn, SLO-triggered preemption, and tenant convergence.

use std::sync::Arc;

use heterosparse::config::{Config, DataConfig, DeviceConfig, ModelDims, SgdConfig, Strategy};
use heterosparse::data::pipeline::ShardedDataset;
use heterosparse::data::synthetic::Generator;
use heterosparse::fleet::{co_schedule, LeaseBook, LeaseState, PriorityClass, TenantJob};
use heterosparse::serve::SnapshotRegistry;
use heterosparse::util::prop::{self, VecU64};

// ---------------------------------------------------------------------------
// Property: lease conservation under random grant / revoke / release /
// churn / time-advance sequences.
// ---------------------------------------------------------------------------

const ROSTER: usize = 5;
const TENANTS: usize = 3;
const GRACE: f64 = 0.4;

/// Decode one opcode of the random program and apply it. Ops that are
/// invalid in the current state (granting a leased device, revoking with
/// no leases, …) are expected to be refused by the book — the property
/// checks the ledger stays conserved no matter what is thrown at it.
fn apply_op(book: &mut LeaseBook, code: u64, now: &mut f64) {
    match code % 5 {
        0 => {
            let tenant = (code / 5) as usize % TENANTS;
            let device = (code / 31) as usize % ROSTER;
            let prio = match (code / 7) % 3 {
                0 => PriorityClass::BestEffort,
                1 => PriorityClass::Standard,
                _ => PriorityClass::Critical,
            };
            let _ = book.grant(tenant, device, prio, *now);
        }
        1 => {
            // Revoke the live lease whose id hashes closest to the code.
            let ids: Vec<_> = book.leases().iter().map(|l| l.id).collect();
            if !ids.is_empty() {
                let id = ids[(code / 5) as usize % ids.len()];
                book.revoke(id, GRACE, *now, "prop").unwrap();
            }
        }
        2 => {
            let ids: Vec<_> = book.leases().iter().map(|l| l.id).collect();
            if !ids.is_empty() {
                let id = ids[(code / 5) as usize % ids.len()];
                book.release(id, *now, "prop").unwrap();
            }
        }
        3 => {
            // Random roster subset from the code's bits (possibly empty —
            // a fully-dead fleet must still conserve).
            let mask = (code / 5) as usize;
            let active: Vec<usize> = (0..ROSTER).filter(|d| mask & (1 << d) != 0).collect();
            book.set_roster_active(&active, *now);
        }
        _ => {
            // Advance time by up to ~GRACE so drains genuinely expire.
            *now += (code % 97) as f64 * (GRACE / 80.0);
        }
    }
}

#[test]
fn prop_lease_conservation_under_random_churn() {
    let gen = VecU64 { min_len: 1, max_len: 120, item_lo: 0, item_hi: u64::MAX / 2 };
    prop::check(200, 0xF1EE7, gen, |program| {
        let mut book = LeaseBook::new(ROSTER, &(0..ROSTER).collect::<Vec<_>>());
        let mut now = 0.0f64;
        for &code in program {
            apply_op(&mut book, code, &mut now);
            // The sim's contract: expire before relying on the ledger.
            book.expire(now);
            if let Err(e) = book.check_conservation(now) {
                return Err(format!("after code {code} at t={now:.3}: {e}"));
            }
            // Invariant 3 restated: every surviving drain is within grace.
            for l in book.leases() {
                if let LeaseState::Draining { deadline } = l.state {
                    if deadline > now + GRACE + 1e-9 {
                        return Err(format!("{} drains past its grace bound", l.id));
                    }
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// End-to-end co-scheduling.
// ---------------------------------------------------------------------------

fn base_config() -> Config {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 32,
        beta: 4,
        lr_bmax: 0.4,
        mega_batches: 24,
        num_mega_batches: 5,
        initial_batch: 32,
        warmup_mega_batches: 0,
        seed: 7,
        ..Default::default()
    };
    cfg.devices = DeviceConfig {
        count: 4,
        speed_factors: vec![1.0, 1.1, 1.21, 1.32],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 17,
    };
    cfg.data =
        DataConfig { train_samples: 1200, test_samples: 200, avg_nnz: 6.0, ..Default::default() };
    cfg.strategy.kind = Strategy::Adaptive;
    cfg.serve.rate = 2_000.0;
    cfg.serve.duration = 0.5;
    cfg.serve.max_delay = 0.002;
    cfg.serve.max_batch = 16;
    cfg.fleet.decision_window = 0.01;
    cfg.fleet.grace = 0.06;
    cfg.fleet.breach_windows = 2;
    cfg.fleet.clear_windows = 2;
    cfg.validate().unwrap();
    cfg
}

fn jobs_for(base: &Config, n: usize) -> Vec<TenantJob> {
    (0..n)
        .map(|i| {
            let mut cfg = base.clone();
            cfg.sgd.seed = base.sgd.seed + i as u64;
            cfg.data.seed = base.data.seed + 7 * i as u64;
            let gen = Generator::new(&cfg.model, &cfg.data);
            let train = gen.generate(cfg.data.train_samples, 1 + i as u64);
            let test = gen.generate(cfg.data.test_samples, 91 + i as u64);
            TenantJob {
                name: format!("tenant-{i}"),
                weight: 1.0,
                train: Arc::new(ShardedDataset::from_dataset(&train, 512)),
                test: Arc::new(test),
                cfg,
            }
        })
        .collect()
}

#[test]
fn co_schedule_is_deterministic_and_conserves_requests() {
    let base = base_config();
    let run = || {
        let jobs = jobs_for(&base, 2);
        let corpus = jobs[0].train.clone();
        co_schedule(&base, &jobs, Some(corpus), Arc::new(SnapshotRegistry::new()), "det")
            .unwrap()
    };
    let a = run();
    let b = run();

    // Conservation audited every tick, horizon past the training runs.
    assert!(a.conservation_checks > 5, "{} checks", a.conservation_checks);
    assert!(a.horizon > 0.0);

    // Both tenants trained to completion with falling loss.
    assert_eq!(a.tenant_logs.len(), 2);
    for (name, log) in &a.tenant_logs {
        assert_eq!(log.rows.len(), base.sgd.num_mega_batches, "{name}");
        assert!(
            log.rows.last().unwrap().loss < log.rows[0].loss,
            "{name} loss must fall"
        );
        // Shared-clock rows are monotone.
        assert!(log.rows.windows(2).all(|w| w[1].clock > w[0].clock), "{name}");
    }

    // Every admitted request is answered exactly once across lease churn:
    // ids are dense and unique.
    let serve = a.serve.as_ref().expect("serve lane ran");
    let mut ids: Vec<u64> = serve.requests.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), serve.requests.len(), "duplicate answers");
    assert_eq!(ids.last().map(|&i| i as usize + 1), Some(serve.requests.len()), "dropped requests");
    assert!(serve.total_requests() > 100, "traffic actually flowed");

    // Bit-identical repeat: training trajectories and serve tail latency.
    for ((_, la), (_, lb)) in a.tenant_logs.iter().zip(&b.tenant_logs) {
        for (ra, rb) in la.rows.iter().zip(&lb.rows) {
            assert_eq!(ra.loss, rb.loss);
            assert_eq!(ra.clock, rb.clock);
            assert_eq!(ra.active_devices, rb.active_devices);
        }
    }
    let sb = b.serve.as_ref().unwrap();
    assert_eq!(serve.latency_percentile_ms(99.0), sb.latency_percentile_ms(99.0));
    assert_eq!(a.events.len(), b.events.len());
}

#[test]
fn slo_breach_triggers_preemption_and_fair_share_does_not() {
    // An absurdly tight SLO guarantees a breach as soon as traffic flows.
    let mut tight = base_config();
    tight.fleet.slo_p95_ms = 0.05;
    tight.fleet.preemption = true;
    let jobs = jobs_for(&tight, 2);
    let corpus = jobs[0].train.clone();
    let preempt = co_schedule(
        &tight,
        &jobs,
        Some(corpus.clone()),
        Arc::new(SnapshotRegistry::new()),
        "tight",
    )
    .unwrap();
    assert!(preempt.preemptions >= 1, "tight SLO must preempt");
    assert!(preempt.events.iter().any(|e| e.action == "preempt"));
    // After the first preempt event, the serve lane receives a grant.
    let t_pre = preempt.events.iter().find(|e| e.action == "preempt").unwrap().at;
    let serve_tenant = jobs.len(); // serve id follows the training tenants
    assert!(
        preempt
            .events
            .iter()
            .any(|e| e.action == "grant" && e.tenant == serve_tenant && e.at >= t_pre),
        "preemption must turn into a serve-lane grant"
    );

    // Same workload with preemption off: fair share never preempts, and
    // training still completes.
    let mut fair = tight.clone();
    fair.fleet.preemption = false;
    let jobs = jobs_for(&fair, 2);
    let corpus = jobs[0].train.clone();
    let out =
        co_schedule(&fair, &jobs, Some(corpus), Arc::new(SnapshotRegistry::new()), "fair")
            .unwrap();
    assert_eq!(out.preemptions, 0);
    assert!(out.events.iter().all(|e| e.action != "preempt"));
    for (_, log) in &out.tenant_logs {
        assert_eq!(log.rows.len(), fair.sgd.num_mega_batches);
    }
}

#[test]
fn scripted_fleet_churn_rides_through_with_conservation() {
    let mut base = base_config();
    // Window-indexed churn: lose a device at the 3rd decision boundary,
    // regain one at the 12th.
    base.fleet.events = vec!["at_mb=3 remove=1".to_string(), "at_mb=12 add=1".to_string()];
    base.validate().unwrap();
    let jobs = jobs_for(&base, 2);
    let corpus = jobs[0].train.clone();
    let out =
        co_schedule(&base, &jobs, Some(corpus), Arc::new(SnapshotRegistry::new()), "churn")
            .unwrap();
    assert_eq!(out.churn.len(), 2, "{:?}", out.churn);
    assert_eq!(out.churn[0].action, "remove");
    assert_eq!(out.churn[1].action, "add");
    // Conservation held on every tick (co_schedule errs otherwise) and
    // training completed despite the shrunken fleet.
    assert!(out.conservation_checks >= 12);
    for (_, log) in &out.tenant_logs {
        assert_eq!(log.rows.len(), base.sgd.num_mega_batches);
    }
}

#[test]
fn serve_only_co_schedule_replays_a_seeded_registry() {
    let base = base_config();
    // Train one tenant exclusively (it publishes), then serve alone.
    let jobs = jobs_for(&base, 1);
    let corpus = jobs[0].train.clone();
    let registry = Arc::new(SnapshotRegistry::new());
    let trained =
        co_schedule(&base, &jobs, Some(corpus.clone()), registry.clone(), "seed").unwrap();
    assert!(registry.latest_version() > 0, "training published snapshots");
    let serve_only =
        co_schedule(&base, &[], Some(corpus), registry, "serve-only").unwrap();
    assert!(serve_only.tenant_logs.is_empty());
    let log = serve_only.serve.as_ref().unwrap();
    assert!(log.total_requests() > 100);
    assert!((serve_only.horizon - base.serve.duration).abs() < 1e-9);
    // The lane alone on the fleet is at least as fast as under contention.
    let contended = trained.serve.as_ref().unwrap();
    assert!(
        log.latency_percentile_ms(95.0) <= contended.latency_percentile_ms(95.0) * 3.0 + 1.0,
        "exclusive serving should not be wildly slower: {} vs {}",
        log.latency_percentile_ms(95.0),
        contended.latency_percentile_ms(95.0)
    );
}

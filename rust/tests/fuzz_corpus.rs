//! Committed fuzz regression corpus (DESIGN.md §14).
//!
//! Each entry is a *case seed* (the post-mix per-case seed, not the run
//! seed) plus the subsystem mask it is checked under. `replay_seed`
//! regenerates the exact timeline from the seed and re-checks the
//! invariants, so a seed that once exposed a bug keeps guarding against
//! its return forever.
//!
//! To add an entry: take the `case seed 0x…` line from a fuzz failure
//! report (CI nightly uploads `fuzz_counterexamples.json`), fix the bug,
//! then append `(0x…, "all")` here. Entries must never be removed —
//! only their masks widened.

use heterosparse::scenario::fuzz::{case_seed, replay_seed, Subsystems};

/// Literal case seeds pinned forever. The initial population is coverage-
/// diverse seeds picked from early sweeps (small/large pools, rack loss,
/// compound drift ramps) rather than historical failures — the corpus
/// exists from day one so the replay plumbing itself stays exercised.
const CORPUS: &[(u64, &str)] = &[
    (0x5EED_0000_0000_0001, "data"),
    (0x5EED_0000_0000_0002, "data"),
    (0xD15B_A11E_D00D_F00D, "train"),
    (0xCAFE_F00D_BAAD_5EED, "train"),
    (0x0123_4567_89AB_CDEF, "serve"),
    (0xFEDC_BA98_7654_3210, "fleet"),
    (0xA5A5_A5A5_5A5A_5A5A, "cluster"),
    (0x7777_7777_7777_7777, "all"),
];

#[test]
fn corpus_seeds_replay_clean() {
    for &(seed, mask) in CORPUS {
        let subs = Subsystems::parse(mask).expect("corpus masks are valid");
        if let Err(msg) = replay_seed(seed, &subs) {
            panic!("corpus seed 0x{seed:016x} (mask '{mask}') regressed: {msg}");
        }
    }
}

/// The PR-gating CI smoke runs `experiment fuzz --seed 7 --runs 50`; its
/// first cases double as corpus entries via the pinned seed-mix function,
/// so a mix change that silently re-maps the whole sweep fails here, not
/// just in the (relational) unit test.
#[test]
fn default_sweep_prefix_replays_clean() {
    for index in 0..2 {
        let seed = case_seed(7, index);
        if let Err(msg) = replay_seed(seed, &Subsystems::all()) {
            panic!("default-sweep case #{index} (case seed 0x{seed:016x}) regressed: {msg}");
        }
    }
}

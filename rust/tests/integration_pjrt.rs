//! Integration: the AOT artifacts executed through PJRT must match the
//! pure-Rust reference twin bit-for-bit at f32 tolerance, and full training
//! through the artifacts must learn.
//!
//! **Environment-gated:** these tests need (a) the `pjrt` cargo feature —
//! without it `Runtime::load` returns the stub error — and (b) the AOT
//! artifacts from `make artifacts`. When either is missing every test
//! skips with a loud message instead of failing, so plain `cargo test`
//! stays green on a fresh offline checkout.

use std::path::Path;

use heterosparse::config::Config;
use heterosparse::coordinator::backend::{PjrtBackend, RefBackend, StepBackend};
use heterosparse::data::batcher::{Batcher, EvalBatches};
use heterosparse::data::synthetic::Generator;
use heterosparse::model::ModelState;
use heterosparse::runtime::Runtime;

fn runtime_or_skip() -> Option<Runtime> {
    let cfg = Config::default();
    let dir = Path::new(&cfg.runtime.artifacts_dir);
    match Runtime::load(dir) {
        Ok(rt) => {
            rt.manifest.check_config(&cfg).expect("artifacts must match default config");
            Some(rt)
        }
        Err(e) => {
            eprintln!("SKIP: artifacts unavailable ({e}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn pjrt_step_matches_reference_numerics() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = Config::default();
    let train = Generator::new(&cfg.model, &cfg.data).generate(600, 1);
    let mut batcher = Batcher::new(&train, &cfg.model, 11);

    let pjrt = PjrtBackend::new(rt);
    let refb = RefBackend;

    let mut m_pjrt = ModelState::init(&cfg.model, 42);
    let mut m_ref = m_pjrt.clone();

    // Several steps across several buckets, including a masked partial batch.
    for (bucket, valid) in [(128usize, 128usize), (64, 64), (16, 16), (32, 20)] {
        let batch = batcher.next_batch(bucket, valid);
        let (loss_p, _) = pjrt.step(&mut m_pjrt, &batch, 0.05).unwrap();
        let (loss_r, _) = refb.step(&mut m_ref, &batch, 0.05).unwrap();
        assert!(
            (loss_p - loss_r).abs() < 1e-3,
            "bucket {bucket}: loss {loss_p} vs {loss_r}"
        );
        let diff = m_pjrt.max_abs_diff(&m_ref);
        assert!(diff < 5e-3, "bucket {bucket}: params diverged by {diff}");
    }
}

#[test]
fn pjrt_eval_matches_reference_predictions() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = Config::default();
    let test = Generator::new(&cfg.model, &cfg.data).generate(512, 2);
    let eval_batch = rt.manifest.eval_batch;
    let eb = EvalBatches::new(&test, &cfg.model, eval_batch);
    let model = ModelState::init(&cfg.model, 9);

    let pjrt = PjrtBackend::new(rt);
    let refb = RefBackend;
    let mut agree = 0usize;
    let mut total = 0usize;
    for batch in &eb.batches {
        let p = pjrt.eval(&model, batch).unwrap();
        let r = refb.eval(&model, batch).unwrap();
        for i in 0..batch.valid {
            total += 1;
            if p[i] == r[i] {
                agree += 1;
            }
        }
    }
    // Argmax ties under f32 reassociation may flip a stray prediction.
    assert!(agree as f64 / total as f64 > 0.99, "only {agree}/{total} predictions agree");
}

#[test]
fn pjrt_step_is_deterministic() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = Config::default();
    let train = Generator::new(&cfg.model, &cfg.data).generate(200, 1);
    let mut batcher = Batcher::new(&train, &cfg.model, 3);
    let batch = batcher.next_batch(64, 64);

    let run = |rt: &Runtime| {
        let mut m = ModelState::init(&cfg.model, 5);
        let (loss, _) = rt.step(&mut m, &batch, 0.05).unwrap();
        (loss, m.w1[1234], m.w2[777])
    };
    let a = run(&rt);
    let b = run(&rt);
    assert_eq!(a, b, "same inputs must produce identical outputs");
}

#[test]
fn all_buckets_compile_and_execute() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = Config::default();
    let train = Generator::new(&cfg.model, &cfg.data).generate(300, 1);
    let mut batcher = Batcher::new(&train, &cfg.model, 4);
    let mut m = ModelState::init(&cfg.model, 6);
    for &bucket in &rt.manifest.buckets {
        let batch = batcher.next_batch(bucket, bucket);
        let (loss, _) = rt.step(&mut m, &batch, 0.01).unwrap();
        assert!(loss.is_finite(), "bucket {bucket} produced non-finite loss");
    }
    assert_eq!(rt.compiled_buckets(), rt.manifest.buckets.len());
}

#[test]
fn full_training_through_pjrt_learns() {
    if runtime_or_skip().is_none() {
        return;
    }
    use heterosparse::coordinator::trainer::TrainerOptions;
    use heterosparse::harness::{run_single, Backend};

    let mut cfg = Config::default();
    cfg.data.train_samples = 4_000;
    cfg.data.test_samples = 600;
    cfg.sgd.lr_bmax = 0.3;
    cfg.sgd.num_mega_batches = 5;
    cfg.sgd.mega_batches = 10;
    cfg.validate().unwrap();

    let log = run_single(&cfg, Backend::Pjrt, TrainerOptions::default()).unwrap();
    assert_eq!(log.rows.len(), 5);
    assert!(
        log.rows.last().unwrap().loss < log.rows[0].loss,
        "loss must decrease: {} -> {}",
        log.rows[0].loss,
        log.rows.last().unwrap().loss
    );
    assert!(log.best_accuracy() > 0.1, "P@1 {}", log.best_accuracy());
}

#[test]
fn threaded_engine_with_pjrt_runs() {
    if runtime_or_skip().is_none() {
        return;
    }
    use heterosparse::config::ExecMode;
    use heterosparse::coordinator::trainer::TrainerOptions;
    use heterosparse::harness::{run_single, Backend};

    let mut cfg = Config::default();
    cfg.runtime.mode = ExecMode::Real;
    cfg.data.train_samples = 2_000;
    cfg.data.test_samples = 300;
    cfg.devices.count = 2;
    cfg.devices.speed_factors = vec![1.0, 1.3];
    cfg.sgd.lr_bmax = 0.3;
    cfg.sgd.num_mega_batches = 2;
    cfg.sgd.mega_batches = 5;
    cfg.validate().unwrap();

    let log = run_single(&cfg, Backend::Pjrt, TrainerOptions::default()).unwrap();
    assert_eq!(log.rows.len(), 2);
    assert!(log.rows.iter().all(|r| r.loss.is_finite()));
    // Real wall clock advanced.
    assert!(log.rows.last().unwrap().clock > 0.0);
}

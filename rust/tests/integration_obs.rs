//! Integration tests for the unified observability plane: bit-determinism
//! of the Chrome-trace export in virtual mode, the span open/close balance
//! and per-lane timestamp monotonicity under scripted churn, and the
//! acceptance gate that a disabled `[obs]` block changes no output byte.

use std::collections::BTreeMap;

use heterosparse::cluster::{self, ClusterPolicy};
use heterosparse::config::{Config, DataConfig, DeviceConfig, ModelDims, ObsConfig, SgdConfig, Strategy};
use heterosparse::coordinator::backend::RefBackend;
use heterosparse::coordinator::engine_sim::SimEngine;
use heterosparse::coordinator::trainer::{Trainer, TrainerOptions};
use heterosparse::coordinator::DevicePool;
use heterosparse::data::synthetic::Generator;
use heterosparse::metrics::RunLog;
use heterosparse::obs::{chrome, ObsHandle, TraceEvent};
use heterosparse::runtime::CostModel;

fn small_cfg(g: usize) -> Config {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 32,
        beta: 4,
        lr_bmax: 0.4,
        mega_batches: 24,
        num_mega_batches: 8,
        initial_batch: 32,
        seed: 7,
        ..Default::default()
    };
    cfg.devices = DeviceConfig {
        count: g,
        speed_factors: vec![1.0; g],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 17,
    };
    cfg.data =
        DataConfig { train_samples: 1200, test_samples: 240, avg_nnz: 6.0, ..Default::default() };
    cfg.strategy.kind = Strategy::Adaptive;
    cfg.validate().unwrap();
    cfg
}

fn cluster_cfg() -> Config {
    let mut cfg = small_cfg(2);
    cfg.cluster.servers = 2;
    cfg.cluster.sync_every = 2;
    cfg.cluster.link_latency_s = 1e-3;
    cfg.cluster.link_gbytes_per_sec = 0.01;
    cfg.cluster.events = vec![
        "at_mb=1 link=1 factor=5.0".to_string(),
        "at_mb=3 server=1 down".to_string(),
        "at_mb=6 server=1 up".to_string(),
    ];
    cfg.validate().unwrap();
    cfg
}

fn enabled_handle() -> ObsHandle {
    ObsHandle::from_config(&ObsConfig { enabled: true, ..ObsConfig::default() }, false)
}

fn run_single(cfg: &Config, opts: TrainerOptions) -> RunLog {
    let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
    let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
    let backend = RefBackend;
    let engine =
        Box::new(SimEngine::new(&backend, DevicePool::roster(cfg), CostModel::default()));
    let mut trainer = Trainer::new(cfg.clone(), engine, &backend, opts);
    trainer.run(&train, &test).unwrap()
}

#[test]
fn cluster_trace_export_is_bit_deterministic() {
    // Two runs of the same virtual-clock cluster scenario — link throttle
    // plus a rack loss/recovery — must export byte-identical traces.
    let cfg = cluster_cfg();
    let policy = ClusterPolicy { flat: false, adaptive: true };

    let obs_a = enabled_handle();
    cluster::run_cluster_with(&cfg, policy, "det", obs_a.clone()).unwrap();
    let trace_a = chrome::render(obs_a.sink());

    let obs_b = enabled_handle();
    cluster::run_cluster_with(&cfg, policy, "det", obs_b.clone()).unwrap();
    let trace_b = chrome::render(obs_b.sink());

    assert_eq!(trace_a, trace_b, "virtual-mode trace export is not bit-deterministic");
    assert!(chrome::validate(&trace_a).unwrap() > 0);

    // The timeline carries the cluster story: tier-2 sync spans with the
    // cadence context, the rack churn instants, and one process lane per
    // server.
    let events = obs_a.sink().events();
    assert!(events.iter().any(|e| e.name == "cluster.sync"));
    assert!(events.iter().any(|e| e.name == "cluster.rack_down"));
    assert!(events.iter().any(|e| e.name == "cluster.rack_up"));
    assert!(events.iter().any(|e| e.name == "engine.step"));
    let pids: std::collections::BTreeSet<u32> = events.iter().map(|e| e.pid).collect();
    assert!(
        pids.contains(&0) && pids.contains(&1),
        "expected one process lane per server, saw {pids:?}"
    );
    assert_eq!(obs_a.sink().dropped(), 0, "default ring must hold this scenario");
}

#[test]
fn spans_balance_and_lanes_stay_monotonic_under_churn() {
    // A single-server run with scripted pool churn: every opened span is
    // closed, and within each (pid, tid) lane virtual timestamps never go
    // backwards (Perfetto renders exactly this ordering).
    let mut cfg = small_cfg(3);
    cfg.elastic.events = vec!["at_mb=2 remove=1".to_string(), "at_mb=5 add=1".to_string()];
    cfg.validate().unwrap();

    let obs = enabled_handle();
    let opts = TrainerOptions { obs: obs.clone(), ..TrainerOptions::default() };
    let log = run_single(&cfg, opts);
    assert!(!log.rows.is_empty());

    let (opened, closed) = obs.sink().balance();
    assert!(opened > 0, "an instrumented run must record spans");
    assert_eq!(opened, closed, "span open/close imbalance");

    let events = obs.sink().events();
    assert!(events.iter().any(|e| e.name == "train.pool"), "churn instants missing");
    assert!(events.iter().any(|e| e.name == "train.megabatch"));
    assert!(events.iter().any(|e| e.name == "train.merge"));
    assert!(events.iter().any(|e| e.name == "engine.step" && e.tid >= 1));

    let mut lanes: BTreeMap<(u32, u32), Vec<&TraceEvent>> = BTreeMap::new();
    for e in &events {
        assert!(e.dur >= 0.0, "negative duration on {}", e.name);
        assert!(e.ts.is_finite() && e.ts >= 0.0, "bad timestamp on {}", e.name);
        lanes.entry((e.pid, e.tid)).or_default().push(e);
    }
    for ((pid, tid), lane) in &lanes {
        for pair in lane.windows(2) {
            assert!(
                pair[1].ts >= pair[0].ts,
                "lane ({pid},{tid}): {} at {} precedes {} at {}",
                pair[1].name,
                pair[1].ts,
                pair[0].name,
                pair[0].ts
            );
        }
    }
}

#[test]
fn disabled_obs_block_changes_no_output_byte() {
    // The acceptance gate: a config that spells out a disabled [obs]
    // block must produce CSV and JSON byte-identical to a config that
    // never mentions it — and neither may contain a metrics section.
    let cfg_plain = small_cfg(2);
    let mut cfg_obs = cfg_plain.clone();
    cfg_obs.obs.enabled = false;
    cfg_obs.obs.level = "debug".to_string();
    cfg_obs.obs.buffer_events = 128;
    cfg_obs.validate().unwrap();

    let log_plain = run_single(&cfg_plain, TrainerOptions::default());
    let log_obs = run_single(&cfg_obs, TrainerOptions::default());

    let dir = std::env::temp_dir().join("hs_integration_obs");
    std::fs::create_dir_all(&dir).unwrap();
    let render = |log: &RunLog, tag: &str| -> (String, String) {
        let csv = dir.join(format!("{tag}.csv"));
        let json = dir.join(format!("{tag}.json"));
        log.write_csv(&csv).unwrap();
        log.write_json(&json).unwrap();
        (std::fs::read_to_string(csv).unwrap(), std::fs::read_to_string(json).unwrap())
    };
    let (csv_plain, json_plain) = render(&log_plain, "plain");
    let (csv_obs, json_obs) = render(&log_obs, "obs_off");
    assert_eq!(csv_plain, csv_obs, "disabled [obs] perturbed the CSV");
    assert_eq!(json_plain, json_obs, "disabled [obs] perturbed the JSON");
    assert!(!csv_plain.contains("metric,kind,value"));
    assert!(!json_plain.contains("\"metrics\""));

    // Flipping collection on must not perturb the training trajectory —
    // it only adds the metrics section on top.
    let enabled = enabled_handle();
    let log_on =
        run_single(&cfg_plain, TrainerOptions { obs: enabled, ..TrainerOptions::default() });
    assert_eq!(log_plain.rows.len(), log_on.rows.len());
    for (a, b) in log_plain.rows.iter().zip(&log_on.rows) {
        assert_eq!(a.clock, b.clock);
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.updates, b.updates);
    }
    assert!(!log_on.metrics.is_empty(), "enabled run must snapshot the registry");
    let (csv_on, json_on) = render(&log_on, "obs_on");
    assert!(csv_on.contains("metric,kind,value"));
    assert!(csv_on.contains("data."), "migrated pipeline counters missing from the export");
    assert!(json_on.contains("\"metrics\""));
}

//! Integration over the coordinator without artifacts (hermetic): strategy
//! end-to-end runs, engine cross-checks, and property tests on routing.

use heterosparse::config::{
    CompositionPolicy, Config, DataConfig, DeviceConfig, ExecMode, ModelDims, SgdConfig, Strategy,
};
use heterosparse::coordinator::backend::RefBackend;
use heterosparse::coordinator::engine_sim::SimEngine;
use heterosparse::coordinator::plan::{DispatchMode, DispatchPlan, ExecutionEngine};
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::data::batcher::Batcher;
use heterosparse::data::pipeline::{DataPlane, ShardedDataset};
use heterosparse::data::synthetic::Generator;
use heterosparse::harness::{run_single, Backend};
use heterosparse::model::ModelState;
use heterosparse::runtime::{CostModel, SimDevice};
use heterosparse::util::prop;
use std::sync::Arc;

fn small_cfg(strategy: Strategy, mode: ExecMode) -> Config {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 32,
        beta: 4,
        lr_bmax: 0.4,
        mega_batches: 16,
        num_mega_batches: 5,
        initial_batch: 32,
        warmup_mega_batches: 0,
        seed: 3,
        ..Default::default()
    };
    cfg.devices = DeviceConfig {
        count: 3,
        speed_factors: vec![1.0, 1.15, 1.32],
        jitter: 0.02,
        nnz_sensitivity: 1.0,
        seed: 11,
    };
    cfg.data = DataConfig { train_samples: 2_000, test_samples: 400, avg_nnz: 6.0, ..Default::default() };
    cfg.runtime.mode = mode;
    cfg.strategy.kind = strategy;
    cfg.validate().unwrap();
    cfg
}

#[test]
fn every_strategy_learns_in_both_engines() {
    for mode in [ExecMode::Virtual, ExecMode::Real] {
        for strategy in Strategy::all() {
            let cfg = small_cfg(strategy, mode);
            let log = run_single(&cfg, Backend::Reference, TrainerOptions::default())
                .unwrap_or_else(|e| panic!("{strategy:?}/{mode:?}: {e}"));
            assert!(!log.rows.is_empty());
            let first = log.rows[0].loss;
            let last = log.rows.last().unwrap().loss;
            assert!(
                last < first + 0.05,
                "{strategy:?}/{mode:?}: loss {first} -> {last}"
            );
        }
    }
}

#[test]
fn adaptive_beats_elastic_under_heavy_skew() {
    // With strong heterogeneity the dynamic scheduler finishes the same
    // sample budget in less (virtual) time than the static allocation.
    let mut a_cfg = small_cfg(Strategy::Adaptive, ExecMode::Virtual);
    let mut e_cfg = small_cfg(Strategy::Elastic, ExecMode::Virtual);
    for cfg in [&mut a_cfg, &mut e_cfg] {
        cfg.devices.speed_factors = vec![1.0, 1.5, 2.0];
        cfg.devices.jitter = 0.0;
        cfg.sgd.num_mega_batches = 6;
    }
    let a = run_single(&a_cfg, Backend::Reference, TrainerOptions::default()).unwrap();
    let e = run_single(&e_cfg, Backend::Reference, TrainerOptions::default()).unwrap();
    let a_clock = a.rows.last().unwrap().clock;
    let e_clock = e.rows.last().unwrap().clock;
    assert!(
        a_clock < e_clock,
        "adaptive should finish the sample budget faster: {a_clock} vs {e_clock}"
    );
}

/// Property: the dynamic scheduler conserves the sample budget exactly for
/// random budgets and random (grid-valid) batch-size assignments.
#[test]
fn prop_dynamic_routing_conserves_budget() {
    let dims = ModelDims { features: 64, hidden: 4, classes: 16, max_nnz: 4, max_labels: 2 };
    let data_cfg = DataConfig { train_samples: 300, avg_nnz: 3.0, ..Default::default() };
    let ds = Generator::new(&dims, &data_cfg).generate(300, 1);
    let dev_cfg = DeviceConfig {
        count: 3,
        speed_factors: vec![1.0, 1.2, 1.4],
        jitter: 0.05,
        nnz_sensitivity: 1.0,
        seed: 5,
    };

    let sharded = Arc::new(ShardedDataset::from_dataset(&ds, 100));
    let gen = prop::Pair(
        prop::U64Range { lo: 1, hi: 700 },
        prop::VecU64 { min_len: 3, max_len: 4, item_lo: 1, item_hi: 5 },
    );
    prop::check(40, 0xDADA, gen, |(budget, size_picks)| {
        let backend = RefBackend;
        let mut engine =
            SimEngine::new(&backend, SimDevice::fleet(&dev_cfg), CostModel::default());
        let plane =
            DataPlane::new_sync(sharded.clone(), &dims, CompositionPolicy::Shuffled, *budget ^ 77);
        let mut replicas = vec![ModelState::init(&dims, 1); 3];
        let batch_sizes: Vec<usize> = size_picks.iter().map(|&p| 8 * p as usize).collect();
        let plan = DispatchPlan {
            mode: DispatchMode::Dynamic,
            device_ids: vec![0, 1, 2],
            batch_sizes,
            lrs: vec![0.05; 3],
            sample_budget: *budget as usize,
            crossbow_rate: None,
            nnz_estimate: 3.0,
            predicted_step_secs: None,
        };
        let report = engine
            .run_mega_batch(&mut replicas, &plane, &plan)
            .map_err(|e| e.to_string())?;
        if report.total_samples() != *budget {
            return Err(format!(
                "budget {} but processed {}",
                budget,
                report.total_samples()
            ));
        }
        // Updates × batch sizes must cover the budget (batches may be partial
        // only at the tail).
        if report.per_device.iter().any(|d| d.busy < 0.0) {
            return Err("negative busy time".into());
        }
        Ok(())
    });
}

/// Property: samples within one batcher epoch are unique (no sample is
/// processed twice before the whole dataset is seen) — routing correctness
/// at the data layer.
#[test]
fn prop_epoch_uniqueness_under_random_batch_sizes() {
    let dims = ModelDims { features: 64, hidden: 4, classes: 16, max_nnz: 4, max_labels: 2 };
    let data_cfg = DataConfig { train_samples: 200, avg_nnz: 3.0, ..Default::default() };
    let ds = Generator::new(&dims, &data_cfg).generate(200, 1);

    let gen = prop::VecU64 { min_len: 1, max_len: 12, item_lo: 1, item_hi: 40 };
    prop::check(60, 0xFEED, gen, |sizes| {
        let mut batcher = Batcher::new(&ds, &dims, sizes.iter().sum::<u64>());
        let mut seen = std::collections::HashSet::new();
        let mut drawn = 0usize;
        for &s in sizes {
            let s = s as usize;
            if drawn + s > 200 {
                break;
            }
            let b = batcher.next_batch(s, s);
            drawn += s;
            for &id in &b.sample_ids {
                if !seen.insert(id) {
                    return Err(format!("sample {id} drawn twice within an epoch"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn run_logs_are_written_and_parse_back() {
    let cfg = small_cfg(Strategy::Adaptive, ExecMode::Virtual);
    let log = run_single(&cfg, Backend::Reference, TrainerOptions::default()).unwrap();
    let dir = std::env::temp_dir().join("hs-int-logs");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("run.csv");
    let json = dir.join("run.json");
    log.write_csv(&csv).unwrap();
    log.write_json(&json).unwrap();
    let parsed = heterosparse::util::json::Json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
    assert_eq!(parsed.get("rows").as_arr().unwrap().len(), log.rows.len());
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(csv_text.lines().count(), log.rows.len() + 1);
}

#[test]
fn gradient_aggregation_equals_model_averaging_single_round() {
    // Analytical sanity from §2.2: for one SGD step from a common model,
    // averaging the updated replicas equals applying the averaged gradient.
    let dims = ModelDims { features: 64, hidden: 8, classes: 16, max_nnz: 4, max_labels: 2 };
    let data_cfg = DataConfig { train_samples: 64, avg_nnz: 3.0, ..Default::default() };
    let ds = Generator::new(&dims, &data_cfg).generate(64, 1);
    let mut batcher = Batcher::new(&ds, &dims, 1);
    let m0 = ModelState::init(&dims, 4);
    let lr = 0.1f32;

    let b1 = batcher.next_batch(16, 16);
    let b2 = batcher.next_batch(16, 16);

    // Model averaging of one-step replicas.
    let mut r1 = m0.clone();
    let mut r2 = m0.clone();
    heterosparse::model::reference::sgd_step_ref(&mut r1, &b1, lr);
    heterosparse::model::reference::sgd_step_ref(&mut r2, &b2, lr);
    let mut avg = ModelState::zeros(&dims);
    avg.set_weighted_sum(&[&r1, &r2], &[0.5, 0.5]);

    // Averaged-gradient step: m0 - lr/2 * (g1 + g2). Recover g via lr=1 runs.
    let mut g1 = m0.clone();
    let mut g2 = m0.clone();
    heterosparse::model::reference::sgd_step_ref(&mut g1, &b1, 1.0);
    heterosparse::model::reference::sgd_step_ref(&mut g2, &b2, 1.0);
    let mut agg = m0.clone();
    // agg += lr/2 * ((g1 - m0) + (g2 - m0))
    agg.add_scaled_diff(&g1, &m0, lr as f64 / 2.0);
    agg.add_scaled_diff(&g2, &m0, lr as f64 / 2.0);

    assert!(avg.max_abs_diff(&agg) < 1e-5, "diff {}", avg.max_abs_diff(&agg));
}

#[test]
fn single_device_strategies_coincide() {
    // On one device Adaptive and Elastic are the same algorithm (Fig. 6
    // plots them as one curve). Verify trajectories match exactly in
    // deterministic virtual time.
    let mut a_cfg = small_cfg(Strategy::Adaptive, ExecMode::Virtual);
    let mut e_cfg = small_cfg(Strategy::Elastic, ExecMode::Virtual);
    for cfg in [&mut a_cfg, &mut e_cfg] {
        cfg.devices = DeviceConfig {
            count: 1,
            speed_factors: vec![1.0],
            jitter: 0.0,
            nnz_sensitivity: 1.0,
            seed: 11,
        };
        cfg.sgd.num_mega_batches = 3;
    }
    let a = run_single(&a_cfg, Backend::Reference, TrainerOptions::default()).unwrap();
    let e = run_single(&e_cfg, Backend::Reference, TrainerOptions::default()).unwrap();
    for (ra, re) in a.rows.iter().zip(&e.rows) {
        assert!((ra.loss - re.loss).abs() < 1e-9, "losses diverge: {} vs {}", ra.loss, re.loss);
        assert_eq!(ra.accuracy, re.accuracy);
    }
}

/// Failure injection: a worker whose backend dies mid-run must surface an
/// error from `run_mega_batch` (no hang, no poisoned engine teardown).
#[test]
fn threaded_engine_surfaces_worker_failure() {
    use heterosparse::coordinator::backend::StepBackend;
    use heterosparse::coordinator::engine_threaded::{BackendFactory, ThreadedEngine};
    use heterosparse::data::PaddedBatch;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    struct FailingBackend {
        remaining: AtomicU32,
    }
    impl StepBackend for FailingBackend {
        fn step(
            &self,
            model: &mut ModelState,
            batch: &PaddedBatch,
            lr: f32,
        ) -> heterosparse::Result<(f32, f64)> {
            if self.remaining.fetch_sub(1, Ordering::Relaxed) == 0 {
                anyhow::bail!("injected device fault");
            }
            let loss = heterosparse::model::reference::sgd_step_ref(model, batch, lr);
            Ok((loss, 1e-6))
        }
        fn eval(&self, m: &ModelState, b: &PaddedBatch) -> heterosparse::Result<Vec<i32>> {
            Ok(heterosparse::model::reference::eval_ref(m, b))
        }
        fn name(&self) -> &'static str {
            "failing"
        }
    }

    let dims = ModelDims { features: 64, hidden: 4, classes: 16, max_nnz: 4, max_labels: 2 };
    let data_cfg = DataConfig { train_samples: 300, avg_nnz: 3.0, ..Default::default() };
    let ds = Generator::new(&dims, &data_cfg).generate(300, 1);
    let dev_cfg = DeviceConfig {
        count: 2,
        speed_factors: vec![1.0, 1.2],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 3,
    };
    let factory: BackendFactory = Arc::new(|dev| {
        Ok(Box::new(FailingBackend {
            // Device 1 fails on its third step; device 0 keeps working.
            remaining: AtomicU32::new(if dev == 1 { 2 } else { u32::MAX }),
        }) as Box<dyn StepBackend>)
    });
    let template = ModelState::init(&dims, 1);
    let mut engine =
        ThreadedEngine::spawn(factory, SimDevice::fleet(&dev_cfg), &template).unwrap();
    let sharded = Arc::new(ShardedDataset::from_dataset(&ds, 100));
    let plane = DataPlane::new_sync(sharded, &dims, CompositionPolicy::Shuffled, 4);
    let mut replicas = vec![template.clone(); 2];
    let plan = DispatchPlan {
        mode: DispatchMode::Dynamic,
        device_ids: vec![0, 1],
        batch_sizes: vec![8, 8],
        lrs: vec![0.05; 2],
        sample_budget: 200,
        crossbow_rate: None,
        nnz_estimate: 3.0,
        predicted_step_secs: None,
    };
    let err = engine
        .run_mega_batch(&mut replicas, &plane, &plan)
        .expect_err("worker fault must propagate");
    assert!(format!("{err:#}").contains("injected device fault"), "{err:#}");
}

/// Config files load end to end (TOML subset + validation).
#[test]
fn shipped_config_files_parse_and_validate() {
    for name in ["configs/default.toml", "configs/e2e.toml"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(name);
        let cfg = Config::load(&path, &[]).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        cfg.validate().unwrap();
    }
    // Overrides stack on top of the file.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/default.toml");
    let cfg = Config::load(&path, &[("devices.count".into(), "2".into()),
                                    ("devices.speed_factors".into(), "[1.0, 1.2]".into())])
        .unwrap();
    assert_eq!(cfg.devices.count, 2);
}

/// eval_every > 1 skips evaluations but keeps rows consistent.
#[test]
fn sparse_eval_cadence() {
    let cfg = small_cfg(Strategy::Adaptive, ExecMode::Virtual);
    let opts = TrainerOptions { eval_every: 2, ..Default::default() };
    let log = run_single(&cfg, Backend::Reference, opts).unwrap();
    assert_eq!(log.rows.len(), cfg.sgd.num_mega_batches);
    // Rows between evals repeat the previous accuracy value.
    assert_eq!(log.rows[0].accuracy, 0.0, "mb 0 is not an eval point at cadence 2");
    assert!(log.rows[1].accuracy >= 0.0);
}

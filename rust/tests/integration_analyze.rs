//! Integration tests for the trace analysis plane: the attribution
//! partition invariant across random churn/drift scenarios, bit-exact
//! `report` output across two virtual-mode runs, a self-diff that flags
//! nothing, and the acceptance scenario — a scripted 2x throttle whose
//! victim dominates the critical-path top-K.

use heterosparse::cluster::{self, ClusterPolicy};
use heterosparse::config::{Config, DataConfig, DeviceConfig, ModelDims, ObsConfig, SgdConfig, Strategy};
use heterosparse::coordinator::backend::RefBackend;
use heterosparse::coordinator::engine_sim::SimEngine;
use heterosparse::coordinator::trainer::{Trainer, TrainerOptions};
use heterosparse::coordinator::DevicePool;
use heterosparse::data::synthetic::Generator;
use heterosparse::metrics::RunLog;
use heterosparse::obs::analyze::{attribute, critical_path, diff, top_gaters, DiffThresholds, Report, TraceData};
use heterosparse::obs::ObsHandle;
use heterosparse::runtime::CostModel;

fn small_cfg(g: usize) -> Config {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 32,
        beta: 4,
        lr_bmax: 0.4,
        mega_batches: 24,
        num_mega_batches: 8,
        initial_batch: 32,
        seed: 7,
        ..Default::default()
    };
    cfg.devices = DeviceConfig {
        count: g,
        speed_factors: vec![1.0; g],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 17,
    };
    cfg.data =
        DataConfig { train_samples: 1200, test_samples: 240, avg_nnz: 6.0, ..Default::default() };
    cfg.strategy.kind = Strategy::Adaptive;
    cfg.validate().unwrap();
    cfg
}

fn enabled_handle() -> ObsHandle {
    ObsHandle::from_config(&ObsConfig { enabled: true, ..ObsConfig::default() }, false)
}

fn run_single(cfg: &Config, opts: TrainerOptions) -> RunLog {
    let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
    let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
    let backend = RefBackend;
    let engine =
        Box::new(SimEngine::new(&backend, DevicePool::roster(cfg), CostModel::default()));
    let mut trainer = Trainer::new(cfg.clone(), engine, &backend, opts);
    trainer.run(&train, &test).unwrap()
}

#[test]
fn attribution_partitions_every_lane_across_churn_and_drift() {
    // Property: whatever the scenario throws at the scheduler — pool
    // churn, scripted drift, both — each lane's window decomposes into
    // compute/serve/merge-wait/cluster-sync/idle with no gap and no
    // overlap. The scenarios below vary the churn/drift script; within
    // each, every lane must satisfy |total - sum(categories)| < eps.
    let scenarios: Vec<(&str, Vec<String>, Vec<String>)> = vec![
        ("plain", vec![], vec![]),
        (
            "churn",
            vec!["at_mb=2 remove=1".to_string(), "at_mb=5 add=1".to_string()],
            vec![],
        ),
        (
            "drift",
            vec![],
            vec![
                "at_mb=1 device=0 factor=2.5 ramp=2".to_string(),
                "at_mb=5 device=0 factor=1.0".to_string(),
            ],
        ),
        (
            "churn+drift",
            vec!["at_mb=3 remove=1".to_string(), "at_mb=6 add=1".to_string()],
            vec!["at_mb=2 device=1 factor=3.0".to_string()],
        ),
    ];
    for (name, elastic, drift) in scenarios {
        let mut cfg = small_cfg(3);
        cfg.elastic.events = elastic;
        cfg.calibration.events = drift;
        cfg.validate().unwrap();

        let obs = enabled_handle();
        let opts = TrainerOptions { obs: obs.clone(), ..TrainerOptions::default() };
        run_single(&cfg, opts);

        let td = TraceData::from_handle(name, &obs);
        let lanes = attribute(&td.events);
        assert!(lanes.len() >= 4, "[{name}] expected coordinator + device lanes");
        for lane in &lanes {
            let parts =
                [lane.compute, lane.serve, lane.merge_wait, lane.cluster_sync, lane.idle];
            assert!(
                parts.iter().all(|&x| x >= -1e-12),
                "[{name}] {}: negative category {parts:?}",
                lane.label()
            );
            let gap = (lane.total - lane.category_sum()).abs();
            assert!(
                gap < 1e-6,
                "[{name}] {}: categories do not partition the window (total {}, sum {}, gap {gap})",
                lane.label(),
                lane.total,
                lane.category_sum()
            );
        }
        // Device lanes actually computed something.
        assert!(
            lanes.iter().any(|l| l.tid >= 1 && l.compute > 0.0),
            "[{name}] no compute attributed to any device lane"
        );
    }
}

#[test]
fn report_is_bit_deterministic_across_two_virtual_runs() {
    // The full markdown report — attribution tables, critical path,
    // decision audit, counters — must come out byte-identical for two
    // runs of the same virtual-clock cluster scenario.
    let mut cfg = small_cfg(2);
    cfg.cluster.servers = 2;
    cfg.cluster.sync_every = 2;
    cfg.cluster.link_latency_s = 1e-3;
    cfg.cluster.link_gbytes_per_sec = 0.01;
    cfg.cluster.events = vec![
        "at_mb=1 link=1 factor=5.0".to_string(),
        "at_mb=3 server=1 down".to_string(),
        "at_mb=6 server=1 up".to_string(),
    ];
    cfg.validate().unwrap();
    let policy = ClusterPolicy { flat: false, adaptive: true };

    let render = |tag: &str| -> String {
        let obs = enabled_handle();
        cluster::run_cluster_with(&cfg, policy, tag, obs.clone()).unwrap();
        Report::from_trace(&TraceData::from_handle("virtual", &obs)).to_markdown(8)
    };
    let a = render("det");
    let b = render("det");
    assert_eq!(a, b, "report output is not bit-deterministic in virtual mode");
    assert!(a.contains("## Lane time attribution"));
    assert!(a.contains("## Critical path"));
    assert!(a.contains("## Decision audit"));
    assert!(a.contains("cluster.sync") || a.contains("cluster-sync"));
}

#[test]
fn self_diff_flags_no_regressions() {
    let cfg = small_cfg(3);
    let obs = enabled_handle();
    let opts = TrainerOptions { obs: obs.clone(), ..TrainerOptions::default() };
    run_single(&cfg, opts);

    let report = Report::from_trace(&TraceData::from_handle("self", &obs));
    let regs = diff(&report, &report, &DiffThresholds::default());
    assert!(regs.is_empty(), "a report diffed against itself flagged: {regs:?}");
}

#[test]
fn throttled_device_dominates_the_critical_path() {
    // The acceptance scenario: device 2 throttles to 2x its nominal cost
    // from mega-batch 1 on, while the planner (no calibration feedback)
    // keeps dealing it the same batch share. Its lane must gate the
    // majority of mega-batch windows and sit on top of the gater table.
    let mut cfg = small_cfg(3);
    cfg.calibration.events = vec!["at_mb=1 device=2 factor=2.0".to_string()];
    cfg.validate().unwrap();

    let obs = enabled_handle();
    let opts = TrainerOptions { obs: obs.clone(), ..TrainerOptions::default() };
    run_single(&cfg, opts);

    let td = TraceData::from_handle("throttle", &obs);
    let segs = critical_path(&td.events);
    assert!(!segs.is_empty(), "no mega-batch windows extracted");
    let top = top_gaters(&segs, 3);
    assert!(!top.is_empty());
    // tid 3 is device 2's lane.
    assert_eq!(
        top[0].tid, 3,
        "expected the throttled device to top the gater table, got {top:?}"
    );
    let gated_by_victim = segs.iter().filter(|s| s.gate_tid == Some(3)).count();
    assert!(
        gated_by_victim * 2 > segs.len(),
        "throttled device gated only {gated_by_victim}/{} windows",
        segs.len()
    );
}

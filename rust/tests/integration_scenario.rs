//! Integration tests for the unified scenario DSL (DESIGN.md §14): golden
//! fixtures pinning every legacy event string shipped in configs/*.toml
//! and the docs to its parse through the shared grammar, a Display/parse
//! round-trip property over fuzzer-generated timelines, the indexed error
//! messages of every `parsed_events()` path, and `-c` overrides driving
//! `experiment fleet` / `experiment cluster` end to end.

use std::path::Path;

use heterosparse::cli::main_with_args;
use heterosparse::cluster::ClusterEvent;
use heterosparse::config::{Config, ElasticEvent, ElasticOp};
use heterosparse::scenario::{self, fuzz, Mask, ScenarioEvent};
use heterosparse::tuning::DriftEvent;
use heterosparse::util::prop::{self, U64Range};

fn s(args: &[&str]) -> Vec<String> {
    args.iter().map(|a| a.to_string()).collect()
}

fn ov(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
    pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

// ---------------------------------------------------------------------------
// Golden fixtures: the legacy grammars, bit-identical through the shared
// parser
// ---------------------------------------------------------------------------

/// Every elastic event string shipped in configs/ or the docs, with the
/// exact struct the legacy parser produced for it. These are frozen: a
/// grammar change that shifts any of them is a compatibility break.
#[test]
fn golden_elastic_fixtures() {
    let cases: &[(&str, ElasticEvent)] = &[
        // README "[elastic]" + --elastic examples.
        ("at_mb=20 remove=2", ElasticEvent { at_mb: 20, op: ElasticOp::Remove(2) }),
        ("at_mb=40 add=2", ElasticEvent { at_mb: 40, op: ElasticOp::Add(2) }),
        // configs/e2e.toml [elastic].
        ("at_mb=3 remove=2", ElasticEvent { at_mb: 3, op: ElasticOp::Remove(2) }),
        ("at_mb=6 add=2", ElasticEvent { at_mb: 6, op: ElasticOp::Add(2) }),
        // configs/e2e.toml [fleet] (same pool grammar).
        ("at_mb=4 remove=1", ElasticEvent { at_mb: 4, op: ElasticOp::Remove(1) }),
        ("at_mb=10 add=1", ElasticEvent { at_mb: 10, op: ElasticOp::Add(1) }),
        // Targeted id forms (README/DESIGN examples).
        ("at_mb=5 remove_id=0", ElasticEvent { at_mb: 5, op: ElasticOp::RemoveId(0) }),
        ("at_mb=9 add_id=3", ElasticEvent { at_mb: 9, op: ElasticOp::AddId(3) }),
    ];
    for (text, want) in cases {
        assert_eq!(ElasticEvent::parse(text).unwrap(), *want, "{text}");
        // The shared parser agrees with the thin view.
        assert_eq!(
            scenario::parse_event(text, Mask::POOL).unwrap(),
            ScenarioEvent::Pool(*want),
            "{text}"
        );
    }
    // Legacy rejection quirks stay rejected.
    for bad in [
        "at_mb=3",                      // no op
        "remove=1",                     // no at_mb
        "at_mb=3 remove=0",             // no-op count
        "at_mb=3 add=0",
        "at_mb=3 remove=1 add=1",       // two ops
        "at_mb=3 at_mb=4 remove=1",     // dup at_mb
        "at_mb=3 explode=1",            // unknown key
        "at_mb=x remove=1",             // non-integer
    ] {
        assert!(ElasticEvent::parse(bad).is_err(), "{bad} must stay rejected");
    }
    // ... but remove_id=0 names a device, not a count: stays accepted.
    assert!(ElasticEvent::parse("at_mb=1 remove_id=0").is_ok());
}

#[test]
fn golden_drift_fixtures() {
    let cases: &[(&str, DriftEvent)] = &[
        // configs/default.toml [calibration] comment + README.
        (
            "at_mb=10 device=0 factor=1.8 ramp=2",
            DriftEvent { at_mb: 10, device: 0, factor: 1.8, ramp: 2 },
        ),
        (
            "at_mb=30 device=0 factor=1.0 ramp=2",
            DriftEvent { at_mb: 30, device: 0, factor: 1.0, ramp: 2 },
        ),
        // DESIGN.md §10 (ramp omitted = step).
        ("at_mb=5 device=2 factor=2.5", DriftEvent { at_mb: 5, device: 2, factor: 2.5, ramp: 0 }),
    ];
    for (text, want) in cases {
        assert_eq!(DriftEvent::parse(text).unwrap(), *want, "{text}");
        assert_eq!(
            scenario::parse_event(text, Mask::DRIFT).unwrap(),
            ScenarioEvent::Drift(*want),
            "{text}"
        );
    }
    for bad in [
        "at_mb=1 device=0",             // missing factor
        "at_mb=1 factor=2",             // missing device
        "device=0 factor=2",            // missing at_mb
        "at_mb=1 device=0 factor=0",    // factor must be > 0
        "at_mb=1 device=0 device=1 factor=2",
        "at_mb=1 device=0 factor=2 explode=1",
    ] {
        assert!(DriftEvent::parse(bad).is_err(), "{bad} must stay rejected");
    }
}

#[test]
fn golden_cluster_fixtures() {
    let cases: &[(&str, ClusterEvent)] = &[
        // configs/default.toml [cluster] comment, README, DESIGN.md §11.
        (
            "at_mb=8 link=1 factor=6.0 ramp=2",
            ClusterEvent::Link(DriftEvent { at_mb: 8, device: 1, factor: 6.0, ramp: 2 }),
        ),
        ("at_mb=12 server=2 down", ClusterEvent::Rack { at_mb: 12, server: 2, up: false }),
        ("at_mb=20 server=2 up", ClusterEvent::Rack { at_mb: 20, server: 2, up: true }),
        // configs/e2e.toml [cluster].
        (
            "at_mb=3 link=1 factor=8.0",
            ClusterEvent::Link(DriftEvent { at_mb: 3, device: 1, factor: 8.0, ramp: 0 }),
        ),
        (
            "at_mb=6 link=1 factor=1.0",
            ClusterEvent::Link(DriftEvent { at_mb: 6, device: 1, factor: 1.0, ramp: 0 }),
        ),
    ];
    for (text, want) in cases {
        assert_eq!(ClusterEvent::parse(text).unwrap(), *want, "{text}");
    }
    for bad in [
        "at_mb=1 link=0",                   // missing factor
        "at_mb=1 link=0 factor=0",          // factor must be > 0
        "at_mb=1 link=0 factor=2 down",     // state on a link
        "at_mb=1 server=0 factor=2 down",   // factor on a rack
        "at_mb=1 link=0 server=1 factor=2", // both targets
        "at_mb=1 down",                     // no target
        "at_mb=1 server=0",                 // no state
        "at_mb=1 server=0 down up",         // two states
    ] {
        assert!(ClusterEvent::parse(bad).is_err(), "{bad} must stay rejected");
    }
}

/// The shipped e2e config parses through the shared grammar into exactly
/// the structs the legacy parsers produced (the fixture above, but read
/// from the real file so configs and code cannot drift apart).
#[test]
fn shipped_configs_parse_bit_identically() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let cfg = Config::load(&root.join("configs/e2e.toml"), &[]).unwrap();
    assert_eq!(
        cfg.elastic.parsed_events().unwrap(),
        vec![
            ElasticEvent { at_mb: 3, op: ElasticOp::Remove(2) },
            ElasticEvent { at_mb: 6, op: ElasticOp::Add(2) },
        ]
    );
    assert_eq!(
        cfg.cluster.parsed_events().unwrap(),
        vec![
            ClusterEvent::Link(DriftEvent { at_mb: 3, device: 1, factor: 8.0, ramp: 0 }),
            ClusterEvent::Link(DriftEvent { at_mb: 6, device: 1, factor: 1.0, ramp: 0 }),
        ]
    );
    // Fleet churn shares the pool grammar; validate() parses it.
    cfg.validate().unwrap();
    assert_eq!(cfg.fleet.events.len(), 2);

    // default.toml ships empty traces and must stay loadable.
    let cfg = Config::load(&root.join("configs/default.toml"), &[]).unwrap();
    assert!(cfg.elastic.parsed_events().unwrap().is_empty());
    assert!(cfg.calibration.parsed_events().unwrap().is_empty());
    assert!(cfg.cluster.parsed_events().unwrap().is_empty());
}

// ---------------------------------------------------------------------------
// Round-trip property: Display is a parseable canonical form
// ---------------------------------------------------------------------------

/// For any fuzzer-generated timeline, every event's `Display` form parses
/// back to the same event under the full mask, and re-rendering is a
/// fixed point (canonicalization converges in one step).
#[test]
fn display_parse_round_trip_property() {
    prop::check(120, 0xD15B, U64Range { lo: 0, hi: u64::MAX - 1 }, |&seed| {
        let case = fuzz::gen_case(seed);
        let all = case
            .elastic
            .iter()
            .chain(&case.calibration)
            .chain(&case.serve)
            .chain(&case.fleet)
            .chain(&case.cluster);
        for ev in all {
            let text = ev.to_string();
            let back = scenario::parse_event(&text, Mask::ALL)
                .map_err(|e| format!("'{text}' failed to re-parse: {e:#}"))?;
            if back != *ev {
                return Err(format!("'{text}' round-tripped to {back:?}, not {ev:?}"));
            }
            if back.to_string() != text {
                return Err(format!("'{text}' re-rendered as '{back}'"));
            }
        }
        Ok(())
    });
}

/// Canonicalization of key order: the same event spelled with keys in any
/// order renders to one canonical string.
#[test]
fn display_canonicalizes_key_order() {
    let a = scenario::parse_event("factor=2.0 at_mb=7 device=1", Mask::DRIFT).unwrap();
    let b = scenario::parse_event("at_mb=7 device=1 factor=2.0", Mask::DRIFT).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.to_string(), "at_mb=7 device=1 factor=2");
    let r = scenario::parse_event("down server=3 at_mb=2", Mask::CLUSTER).unwrap();
    assert_eq!(r.to_string(), "at_mb=2 server=3 down");
}

// ---------------------------------------------------------------------------
// Indexed error messages on every parsed_events() path
// ---------------------------------------------------------------------------

#[test]
fn parsed_events_errors_name_index_and_line() {
    let mut cfg = Config::default();
    cfg.elastic.events = vec!["at_mb=1 remove=1".to_string(), "garbage".to_string()];
    let err = format!("{:#}", cfg.elastic.parsed_events().unwrap_err());
    assert!(err.contains("elastic.events[1]: 'garbage'"), "{err}");

    let mut cfg = Config::default();
    cfg.calibration.events = vec!["at_mb=1 device=0 factor=0".to_string()];
    let err = format!("{:#}", cfg.calibration.parsed_events().unwrap_err());
    assert!(err.contains("calibration.events[0]: 'at_mb=1 device=0 factor=0'"), "{err}");
    assert!(err.contains("factor must be positive"), "{err}");

    let mut cfg = Config::default();
    cfg.cluster.events =
        vec!["at_mb=1 link=0 factor=2.0".to_string(), "at_mb=2 link=0".to_string()];
    let err = format!("{:#}", cfg.cluster.parsed_events().unwrap_err());
    assert!(err.contains("cluster.events[1]: 'at_mb=2 link=0'"), "{err}");

    // serve/fleet traces are parsed by validate(); same labeling.
    let mut cfg = Config::default();
    cfg.serve.events = vec!["at_mb=1 nonsense".to_string()];
    let err = format!("{:#}", cfg.validate().unwrap_err());
    assert!(err.contains("serve.events[0]: 'at_mb=1 nonsense'"), "{err}");

    let mut cfg = Config::default();
    cfg.fleet.events = vec!["at_mb=1 add=1".to_string(), "at_mb=2 remove=0".to_string()];
    let err = format!("{:#}", cfg.validate().unwrap_err());
    assert!(err.contains("fleet.events[1]: 'at_mb=2 remove=0'"), "{err}");

    // Unknown keys list the family vocabulary so the fix is in the message.
    let mut cfg = Config::default();
    cfg.elastic.events = vec!["at_mb=1 explode=1".to_string()];
    let err = format!("{:#}", cfg.elastic.parsed_events().unwrap_err());
    assert!(err.contains("at_mb|remove|add|remove_id|add_id"), "{err}");
}

// ---------------------------------------------------------------------------
// Compound [scenario] lines route across subsystems
// ---------------------------------------------------------------------------

#[test]
fn scenario_lines_route_and_inherit_at_mb() {
    let cfg = Config::from_overrides(&ov(&[(
        "scenario.events",
        r#"["at_mb=4 server=1 down; link=0 factor=6.0; serve: add=1", "at_mb=9 device=0 factor=1.5 ramp=2"]"#,
    )]))
    .unwrap();
    assert_eq!(
        cfg.cluster.events,
        vec!["at_mb=4 server=1 down".to_string(), "at_mb=4 link=0 factor=6".to_string()]
    );
    assert_eq!(cfg.serve.events, vec!["at_mb=4 add=1".to_string()]);
    assert_eq!(cfg.calibration.events, vec!["at_mb=9 device=0 factor=1.5 ramp=2".to_string()]);
    // Routed lines land in canonical form and stay parseable downstream.
    assert_eq!(cfg.cluster.parsed_events().unwrap().len(), 2);
    assert_eq!(cfg.calibration.parsed_events().unwrap().len(), 1);

    // A bad clause names the line index and the full line.
    let err = format!(
        "{:#}",
        Config::from_overrides(&ov(&[(
            "scenario.events",
            r#"["at_mb=1 remove=1", "at_mb=2 bogus=1"]"#,
        )]))
        .unwrap_err()
    );
    assert!(err.contains("scenario.events[1]: 'at_mb=2 bogus=1'"), "{err}");

    // Routed events flow into validation: a serve clause naming a device
    // outside the roster fails at load time like a hand-written one.
    let err = format!(
        "{:#}",
        Config::from_overrides(&ov(&[
            ("devices.count", "2"),
            ("devices.speed_factors", "[1.0, 1.1]"),
            ("scenario.events", r#"["serve: at_mb=1 remove_id=9"]"#),
        ]))
        .unwrap_err()
    );
    assert!(err.contains("serve.events[0]"), "{err}");
}

// ---------------------------------------------------------------------------
// -c overrides drive the experiments end to end
// ---------------------------------------------------------------------------

/// Shared micro-scale `-c` arguments: every subsystem knob that matters
/// for test runtime, all through the override path under test.
fn micro_overrides() -> Vec<&'static str> {
    vec![
        "-c", "model.features=256",
        "-c", "model.hidden=16",
        "-c", "model.classes=64",
        "-c", "model.max_nnz=12",
        "-c", "model.max_labels=4",
        "-c", "data.train_samples=1200",
        "-c", "data.test_samples=240",
        "-c", "sgd.b_min=8",
        "-c", "sgd.b_max=32",
        "-c", "sgd.beta=4",
        "-c", "sgd.mega_batches=6",
        "-c", "sgd.num_mega_batches=3",
        "-c", "sgd.initial_batch=32",
        "-c", "devices.count=2",
        "-c", "devices.speed_factors=[1.0, 1.1]",
        "-c", "devices.jitter=0.0",
        "-c", "serve.rate=1000",
        "-c", "serve.duration=0.3",
    ]
}

#[test]
fn dashc_drives_experiment_fleet_end_to_end() {
    let mut args = vec!["experiment", "fleet"];
    args.extend(micro_overrides());
    main_with_args(&s(&args)).unwrap();
}

#[test]
fn dashc_drives_experiment_cluster_end_to_end() {
    let mut args = vec!["experiment", "cluster"];
    args.extend(micro_overrides());
    args.extend([
        "-c", "cluster.servers=2",
        "-c", "cluster.sync_every=2",
        "-c", "cluster.link_latency_s=1e-3",
        "-c", "cluster.link_gbytes_per_sec=0.05",
        // The fabric scenario itself arrives via the compound DSL.
        "-c", r#"scenario.events=["at_mb=1 link=1 factor=4.0; at_mb=2 server=1 down"]"#,
    ]);
    main_with_args(&s(&args)).unwrap();
}

// ---------------------------------------------------------------------------
// Fuzzer end-to-end (tiny run; the corpus test replays committed seeds)
// ---------------------------------------------------------------------------

#[test]
fn experiment_fuzz_acceptance_smoke() {
    // The acceptance criterion runs 200 cases in CI; keep the in-test run
    // small but real, spanning every subsystem.
    main_with_args(&s(&["experiment", "fuzz", "--seed", "7", "--runs", "2"])).unwrap();
}

/// The fuzzer's generator and the prop harness shrink the same way: a
/// seeded failing property over generated cases reports a shrunk case.
#[test]
fn fuzz_shrink_produces_valid_smaller_cases() {
    let case = fuzz::gen_case(fuzz::case_seed(7, 3));
    let total = |c: &fuzz::FuzzCase| {
        c.elastic.len() + c.calibration.len() + c.serve.len() + c.fleet.len() + c.cluster.len()
    };
    for cand in fuzz::shrink(&case) {
        assert!(
            total(&cand) < total(&case) || cand.mega_batches < case.mega_batches,
            "shrink candidates must strictly shrink"
        );
        cand.config().expect("shrunk cases stay valid configs");
    }
}

//! Integration tests for the online cost-model calibration plane: the
//! estimator against the real heterogeneity model, step-drift detection,
//! dispatch conservation under calibrated scheduling + pool churn, the
//! static-vs-calibrated rebalancing claim, and the bit-for-bit guarantee
//! that a disabled `[calibration]` block changes nothing.

use heterosparse::config::{Config, DataConfig, DeviceConfig, ModelDims, SgdConfig, Strategy};
use heterosparse::coordinator::backend::RefBackend;
use heterosparse::coordinator::engine_sim::SimEngine;
use heterosparse::coordinator::trainer::{Trainer, TrainerOptions};
use heterosparse::coordinator::DevicePool;
use heterosparse::data::synthetic::Generator;
use heterosparse::data::PaddedBatch;
use heterosparse::metrics::RunLog;
use heterosparse::runtime::{CostModel, SimDevice};
use heterosparse::tuning::{DeviceEstimator, EstimatorConfig, Observation};

fn small_cfg(g: usize) -> Config {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 32,
        beta: 4,
        lr_bmax: 0.4,
        mega_batches: 24,
        num_mega_batches: 10,
        initial_batch: 32,
        seed: 7,
        ..Default::default()
    };
    cfg.devices = DeviceConfig {
        count: g,
        speed_factors: vec![1.0; g],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 17,
    };
    cfg.data =
        DataConfig { train_samples: 1500, test_samples: 300, avg_nnz: 6.0, ..Default::default() };
    cfg.strategy.kind = Strategy::Adaptive;
    cfg.validate().unwrap();
    cfg
}

fn run(cfg: &Config) -> RunLog {
    let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
    let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
    let backend = RefBackend;
    let engine =
        Box::new(SimEngine::new(&backend, DevicePool::roster(cfg), CostModel::default()));
    let mut trainer = Trainer::new(cfg.clone(), engine, &backend, TrainerOptions::default());
    trainer.run(&train, &test).unwrap()
}

fn device_obs(dev: &mut SimDevice, cost: &CostModel, bucket: usize, nnz: usize) -> Observation {
    let mut b = PaddedBatch::with_shape(bucket, 4, 2);
    b.valid = bucket;
    b.nnz = nnz;
    Observation {
        bucket,
        nnz_per_batch: nnz as f64,
        secs_per_batch: dev.step_duration(cost, &b),
    }
}

#[test]
fn estimator_converges_to_a_scripted_true_cost() {
    // A zero-jitter device at factor 1.21 with a 1.5x scripted drift: the
    // true effective speed is 1.815, and the estimator must land within
    // tolerance from a handful of mega-batch observations.
    let cfg = DeviceConfig { jitter: 0.0, ..Default::default() };
    let cost = CostModel::default();
    let mut dev = SimDevice::new(2, &cfg); // factor 1.21
    dev.set_drift(1.5);
    let mut est = DeviceEstimator::new(EstimatorConfig::default(), cost);
    for i in 0..10 {
        let bucket = 16 + 8 * (i % 3);
        let obs = device_obs(&mut dev, &cost, bucket, bucket * 6);
        est.observe(obs);
    }
    let e = est.estimate().expect("estimator has observations");
    let truth = 1.21 * 1.5;
    assert!(
        (e.speed - truth).abs() < 0.05 * truth,
        "estimated {} vs true {truth}",
        e.speed
    );
    assert!(e.residual_rel < 0.02, "zero-jitter fit must be near-exact: {}", e.residual_rel);
    assert_eq!(e.drift_events, 0, "a constant device has no step drift");
}

#[test]
fn step_drift_is_detected_within_the_configured_window() {
    let cfg = DeviceConfig { jitter: 0.0, ..Default::default() };
    let cost = CostModel::default();
    let mut dev = SimDevice::new(0, &cfg); // factor 1.0
    let ecfg = EstimatorConfig { step_obs: 2, ..Default::default() };
    let mut est = DeviceEstimator::new(ecfg, cost);
    for _ in 0..6 {
        let obs = device_obs(&mut dev, &cost, 32, 32 * 6);
        assert!(!est.observe(obs), "steady device must not trip the detector");
    }
    // The device throttles 1.8x: detection must land within step_obs
    // post-change observations, and the fast re-estimate is already at
    // the new speed.
    dev.set_drift(1.8);
    let mut fired_after = None;
    for k in 1..=4 {
        let obs = device_obs(&mut dev, &cost, 32, 32 * 6);
        if est.observe(obs) {
            fired_after = Some(k);
            break;
        }
    }
    assert_eq!(fired_after, Some(2), "step drift must fire after exactly step_obs outliers");
    assert_eq!(est.drift_events(), 1);
    let e = est.estimate().unwrap();
    assert!((e.speed - 1.8).abs() < 0.1, "fast re-estimate at the new speed: {}", e.speed);
}

#[test]
fn calibrated_scheduling_rebalances_updates_under_a_throttle() {
    // Homogeneous 4-device fleet; device 0 throttles 2.5x at mega-batch 3
    // and stays throttled. The static run's batch sizes never change (the
    // stability controller sees a settled grid and keeps Algorithm 1
    // paused), so its update counts stay skewed ~2.5x. The calibrated run
    // detects the step within one window and re-seeds the batch grid from
    // the estimates.
    let mut cfg = small_cfg(4);
    cfg.calibration.events = vec!["at_mb=3 device=0 factor=2.5".to_string()];
    cfg.calibration.step_obs = 1;
    cfg.validate().unwrap();
    let static_log = run(&cfg);

    let mut cal = cfg.clone();
    cal.calibration.enabled = true;
    cal.validate().unwrap();
    let cal_log = run(&cal);

    // Same physical scenario: both runs slow down after the throttle.
    assert_eq!(static_log.rows.len(), 10);
    assert_eq!(cal_log.rows.len(), 10);

    // Post-detection window: mega-batches 5..10.
    let b_static = static_log.window_balance(5, 10);
    let b_cal = cal_log.window_balance(5, 10);
    assert!(b_static > 1.8, "static scheduling stays skewed: {b_static}");
    assert!(b_cal < 1.6, "calibrated scheduling rebalances: {b_cal}");
    assert!(b_cal < b_static, "calibrated must beat static: {b_cal} vs {b_static}");

    // The estimate tracked the throttle and the grid re-seeded.
    let last = cal_log.rows.last().unwrap();
    assert!(
        (last.cost_speed[0] - 2.5).abs() < 0.3,
        "device 0 estimate tracks the drift: {}",
        last.cost_speed[0]
    );
    assert!((last.cost_speed[1] - 1.0).abs() < 0.1, "unthrottled device stays nominal");
    assert!(
        last.batch_sizes[0] < last.batch_sizes[1],
        "throttled device runs smaller batches: {:?}",
        last.batch_sizes
    );

    // Sample conservation holds in both schedules.
    for log in [&static_log, &cal_log] {
        let expect = (cfg.sgd.mega_batch_samples() * cfg.sgd.num_mega_batches) as u64;
        assert_eq!(log.rows.last().unwrap().samples, expect);
    }
}

#[test]
fn calibrated_dispatch_preserves_conservation_under_churn() {
    // Calibration on, plus elastic churn: device 0 leaves at mb 2 and
    // returns at mb 4, while device 1 throttles. Budgets must land
    // exactly, inactive devices must do no work, and the whole run must
    // be bit-reproducible.
    let mut cfg = small_cfg(4);
    cfg.calibration.enabled = true;
    cfg.calibration.events = vec!["at_mb=1 device=1 factor=2.0".to_string()];
    cfg.elastic.events =
        vec!["at_mb=2 remove_id=0".to_string(), "at_mb=4 add_id=0".to_string()];
    cfg.validate().unwrap();

    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.rows.len(), b.rows.len());
    for (x, y) in a.rows.iter().zip(&b.rows) {
        assert_eq!(x.clock, y.clock, "calibrated runs stay deterministic");
        assert_eq!(x.loss, y.loss);
        assert_eq!(x.updates, y.updates);
        assert_eq!(x.cost_speed, y.cost_speed);
    }

    let expect = (cfg.sgd.mega_batch_samples() * cfg.sgd.num_mega_batches) as u64;
    assert_eq!(a.rows.last().unwrap().samples, expect, "budget conserved across churn");
    assert_eq!(a.device_counts(), vec![4, 4, 3, 3, 4, 4, 4, 4, 4, 4]);
    for r in &a.rows {
        for d in 0..4 {
            if !r.active_devices.contains(&d) {
                assert_eq!(r.updates[d], 0, "inactive device did work at mb {}", r.mega_batch);
            }
        }
    }
    // The throttled device's estimate shows up in the telemetry rows.
    let last = a.rows.last().unwrap();
    assert!((last.cost_speed[1] - 2.0).abs() < 0.25, "estimate {}", last.cost_speed[1]);
}

#[test]
fn disabled_calibration_reproduces_static_results_bit_for_bit() {
    // The acceptance gate: with `enabled = false` the plane is inert —
    // whatever the other knobs say, the run is bit-identical to a config
    // that never mentioned [calibration].
    let base = small_cfg(2);
    let plain = run(&base);

    let mut knobs = base.clone();
    knobs.calibration.enabled = false;
    knobs.calibration.window = 12;
    knobs.calibration.alpha = 1.0;
    knobs.calibration.step_threshold = 0.01;
    knobs.calibration.step_obs = 1;
    knobs.validate().unwrap();
    let inert = run(&knobs);

    assert_eq!(plain.rows.len(), inert.rows.len());
    for (x, y) in plain.rows.iter().zip(&inert.rows) {
        assert_eq!(x.clock, y.clock);
        assert_eq!(x.loss, y.loss);
        assert_eq!(x.accuracy, y.accuracy);
        assert_eq!(x.batch_sizes, y.batch_sizes);
        assert_eq!(x.updates, y.updates);
        assert!(x.cost_speed.iter().all(|&s| s == 0.0), "no estimates when disabled");
        assert!(y.cost_speed.iter().all(|&s| s == 0.0));
    }
}

#[test]
fn drift_trace_applies_even_with_calibration_disabled() {
    // The trace is the physical scenario, not the policy: a disabled
    // plane still runs it, and dynamic dispatch visibly shifts work away
    // from the throttled device.
    let mut cfg = small_cfg(4);
    cfg.calibration.events = vec!["at_mb=3 device=0 factor=3.0".to_string()];
    cfg.validate().unwrap();
    let log = run(&cfg);
    let before = log.rows[1].updates[0];
    let after = log.rows[5].updates[0];
    assert!(
        after < before,
        "throttled device must win fewer batches: {before} -> {after}"
    );
    // And the clock slows down relative to an undrifted run.
    let undrifted = run(&small_cfg(4));
    assert!(
        log.rows.last().unwrap().clock > undrifted.rows.last().unwrap().clock,
        "a throttled fleet takes longer"
    );
}

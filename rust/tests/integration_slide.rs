//! Integration tests for the adaptive-sparsity compute lever: the ratio
//! ladder's monotone cost curve, the bit-for-bit guarantee that a dormant
//! `[slide]` block changes nothing, the joint-vs-batch-only rebalancing
//! claim under a hard throttle, and the serve-side SLO fallback.

use std::sync::Arc;

use heterosparse::config::{Config, DataConfig, DeviceConfig, ModelDims, SgdConfig, Strategy};
use heterosparse::coordinator::backend::RefBackend;
use heterosparse::coordinator::engine_sim::SimEngine;
use heterosparse::coordinator::trainer::{Trainer, TrainerOptions};
use heterosparse::coordinator::DevicePool;
use heterosparse::data::pipeline::ShardedDataset;
use heterosparse::data::synthetic::Generator;
use heterosparse::metrics::RunLog;
use heterosparse::model::ModelState;
use heterosparse::runtime::CostModel;
use heterosparse::serve::{replay, ReplayOptions, SnapshotRegistry};

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 32,
        beta: 4,
        lr_bmax: 0.4,
        mega_batches: 24,
        num_mega_batches: 10,
        initial_batch: 32,
        seed: 7,
        ..Default::default()
    };
    cfg.devices = DeviceConfig {
        count: 4,
        speed_factors: vec![1.0; 4],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 17,
    };
    cfg.data =
        DataConfig { train_samples: 1500, test_samples: 300, avg_nnz: 6.0, ..Default::default() };
    cfg.strategy.kind = Strategy::Adaptive;
    cfg.validate().unwrap();
    cfg
}

/// The scripted throttle every scheduling comparison below runs under:
/// 10x on device 0 — past what the batch grid alone can absorb (the
/// equal-time batch falls below `b_min`).
fn throttled_cfg() -> (Config, usize, usize) {
    let mut cfg = small_cfg();
    let throttle_at = 3;
    let recover_at = 8;
    cfg.calibration.events = vec![
        format!("at_mb={throttle_at} device=0 factor=10.0 ramp=1"),
        format!("at_mb={recover_at} device=0 factor=1.0 ramp=1"),
    ];
    cfg.calibration.step_obs = 1;
    cfg.validate().unwrap();
    (cfg, throttle_at, recover_at)
}

fn run(cfg: &Config) -> RunLog {
    let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
    let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
    let backend = RefBackend;
    let engine = Box::new(
        SimEngine::new(&backend, DevicePool::roster(cfg), CostModel::default())
            .with_slide(&cfg.slide),
    );
    let mut trainer = Trainer::new(cfg.clone(), engine, &backend, TrainerOptions::default());
    trainer.run(&train, &test).unwrap()
}

#[test]
fn ladder_cost_is_strictly_monotone_on_a_throttled_device() {
    let cfg = small_cfg();
    let cost = CostModel::default();
    let b = cfg.sgd.b_max;
    let nnz = (cfg.data.avg_nnz * b as f64) as usize;
    let ladder = cfg.slide.ratio_ladder();
    assert!(ladder.len() >= 3, "default ladder has real rungs: {ladder:?}");
    let mut prev = f64::INFINITY;
    for r in ladder {
        let t = 10.0 * cost.step_time_parts_at(b, nnz, r);
        assert!(
            t < prev,
            "per-step cost must strictly decrease down the ladder (ratio {r}: {t} vs {prev})"
        );
        prev = t;
    }
}

/// A `[slide]` block with `adaptive = true` but no drift (and no
/// calibration plane) pins every ratio at 1.0, and ratio-1.0 plans are
/// bit-identical to plans that never heard of sparsity.
#[test]
fn dormant_lever_is_bit_identical() {
    let cfg = run(&small_cfg());
    let mut armed = small_cfg();
    armed.slide.adaptive = true;
    armed.validate().unwrap();
    let armed = run(&armed);
    assert_eq!(cfg.rows.len(), armed.rows.len());
    for (a, b) in cfg.rows.iter().zip(&armed.rows) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "mb {} loss diverged", a.mega_batch);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.updates, b.updates);
        assert_eq!(a.batch_sizes, b.batch_sizes);
        assert!(b.sparsity_ratio.iter().all(|&r| r == 1.0), "no drift -> no shed classes");
    }
}

/// The acceptance claim: under a throttle too hard for the batch grid,
/// joint batch+sparsity re-targeting achieves update balance at least as
/// good as batch-only — and it really does run reduced active sets on the
/// throttled device.
#[test]
fn joint_retargeting_rebalances_at_least_as_well_as_batch_only() {
    let (base, throttle_at, recover_at) = throttled_cfg();

    let mut batch_only = base.clone();
    batch_only.calibration.enabled = true;
    batch_only.validate().unwrap();
    let batch_only = run(&batch_only);

    let mut joint = base.clone();
    joint.calibration.enabled = true;
    joint.slide.adaptive = true;
    joint.validate().unwrap();
    let joint = run(&joint);

    let bal_batch = batch_only.window_balance(throttle_at + 1, recover_at);
    let bal_joint = joint.window_balance(throttle_at + 1, recover_at);
    assert!(
        bal_joint <= bal_batch + 1e-9,
        "joint balance {bal_joint:.3} must not lose to batch-only {bal_batch:.3}"
    );

    // The lever really engaged: some throttled-window row ran device 0
    // sparse, with a truncated per-step active-class count to show for it.
    let classes = base.model.classes as f64;
    let engaged = joint.rows.iter().any(|r| {
        r.mega_batch > throttle_at
            && r.mega_batch < recover_at
            && r.sparsity_ratio[0] < 1.0
            && r.updates[0] > 0
            && r.active_classes[0] > 0.0
            && r.active_classes[0] < classes
    });
    assert!(engaged, "throttled device never shed classes");
    // And batch-only never touches the ratio column.
    assert!(batch_only
        .rows
        .iter()
        .all(|r| r.sparsity_ratio.iter().all(|&x| x == 1.0)));
    // The run still learns: well clear of chance (1/classes ~ 0.016).
    assert!(joint.best_accuracy() > 0.05, "joint run collapsed: {}", joint.best_accuracy());
}

/// Serve-side SLO fallback: with the lever armed at a deliberately tight
/// SLO, the same trace is served with approximate LSH top-k inference and
/// its p99 does not regress past the exact replay's.
#[test]
fn slo_armed_replay_does_not_regress_p99() {
    let cfg = small_cfg();
    let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
    let data = Arc::new(ShardedDataset::from_dataset(&train, cfg.data.pipeline.shard_samples));
    let registry = SnapshotRegistry::new();
    registry.publish(ModelState::init(&cfg.model, 5), Some(0), 0.0);

    let opts = |name: &str| ReplayOptions {
        pattern: cfg.serve.pattern,
        duration: 0.5,
        follow_clock: false,
        train_log: None,
        name: name.to_string(),
        obs: heterosparse::obs::ObsHandle::disabled(),
    };
    let exact = replay(&cfg, data.clone(), &registry, &RefBackend, &opts("exact")).unwrap();

    let mut armed_cfg = cfg.clone();
    armed_cfg.slide.serve_slo_ms = 1e-3; // everything breaches -> approx mode
    armed_cfg.validate().unwrap();
    let armed = replay(&armed_cfg, data, &registry, &RefBackend, &opts("armed")).unwrap();

    assert_eq!(exact.total_requests(), armed.total_requests(), "every request answered once");
    let (p99_exact, p99_armed) =
        (exact.latency_percentile_ms(99.0), armed.latency_percentile_ms(99.0));
    assert!(
        p99_armed <= p99_exact * 1.001,
        "approximate serving must not regress latency: {p99_armed} vs {p99_exact}"
    );
}

//! Integration tests for the cluster scale-out plane: the
//! hierarchical-equals-flat merge identity over random partitions,
//! weights, and churn; bit-determinism of `ClusterSim`; the inert-block
//! guarantee; the adaptive-cadence acceptance scenario; rack loss and
//! recovery; and straggler demotion.

use heterosparse::cluster::{self, hier, ClusterPolicy};
use heterosparse::config::{Config, DataConfig, DeviceConfig, ModelDims, SgdConfig, Strategy};
use heterosparse::coordinator::backend::RefBackend;
use heterosparse::coordinator::engine_sim::SimEngine;
use heterosparse::coordinator::trainer::{Trainer, TrainerOptions};
use heterosparse::coordinator::DevicePool;
use heterosparse::data::synthetic::Generator;
use heterosparse::metrics::RunLog;
use heterosparse::model::ModelState;
use heterosparse::runtime::CostModel;

fn small_cfg(g: usize) -> Config {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 32,
        beta: 4,
        lr_bmax: 0.4,
        mega_batches: 24,
        num_mega_batches: 10,
        initial_batch: 32,
        seed: 7,
        ..Default::default()
    };
    cfg.devices = DeviceConfig {
        count: g,
        speed_factors: vec![1.0; g],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 17,
    };
    cfg.data =
        DataConfig { train_samples: 1500, test_samples: 300, avg_nnz: 6.0, ..Default::default() };
    cfg.strategy.kind = Strategy::Adaptive;
    cfg.validate().unwrap();
    cfg
}

fn cluster_cfg(servers: usize) -> Config {
    let mut cfg = small_cfg(2);
    cfg.cluster.servers = servers;
    cfg.cluster.sync_every = 2;
    cfg.cluster.link_latency_s = 1e-3;
    cfg.cluster.link_gbytes_per_sec = 0.01; // syncs cost visible time
    cfg.validate().unwrap();
    cfg
}

/// xorshift64* — deterministic randomness without a rand dependency.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }
}

#[test]
fn hierarchical_merge_equals_flat_average_over_random_partitions() {
    // The 1e-10 identity: for any partition of devices into servers, any
    // positive weights, and any per-server scales, the two-tier average
    // equals the flat weighted average with device weights w_si * scale_s.
    let dims =
        ModelDims { features: 64, hidden: 8, classes: 16, max_nnz: 6, max_labels: 2 };
    let mut rng = Rng(0x5eed_cafe);
    for trial in 0..40 {
        let devices = 2 + rng.below(10);
        let models: Vec<ModelState> =
            (0..devices).map(|i| ModelState::init(&dims, (trial * 100 + i) as u64 + 1)).collect();
        let weights: Vec<f64> = (0..devices).map(|_| 0.1 + 4.0 * rng.f64()).collect();
        // Random partition with every server non-empty (device i seeds
        // server i % k; the rest land anywhere — churn between trials).
        let k = 1 + rng.below(devices.min(5));
        let mut assign: Vec<usize> = (0..devices).map(|i| i % k).collect();
        for a in assign.iter_mut().skip(k) {
            *a = rng.below(k);
        }
        let scales: Vec<f64> = if trial % 2 == 0 {
            vec![1.0; k] // fresh servers: the exact composition case
        } else {
            (0..k).map(|_| 0.2 + rng.f64()).collect() // stale servers
        };
        let mut servers: Vec<Vec<&ModelState>> = vec![Vec::new(); k];
        let mut dw: Vec<Vec<f64>> = vec![Vec::new(); k];
        let mut flat_w = Vec::new();
        for (i, &s) in assign.iter().enumerate() {
            servers[s].push(&models[i]);
            dw[s].push(weights[i]);
            flat_w.push(weights[i] * scales[s]);
        }
        let refs: Vec<&ModelState> = models.iter().collect();
        let flat = hier::flat_average_f64(&refs, &flat_w);
        let two_tier = hier::hierarchical_average_f64(&servers, &dw, &scales);
        let diff = hier::max_abs_diff_f64(&flat, &two_tier);
        assert!(diff < 1e-10, "trial {trial}: two-tier differs from flat by {diff}");
    }
}

#[test]
fn cluster_sim_is_bit_deterministic() {
    let mut cfg = cluster_cfg(3);
    cfg.cluster.straggler_floor = 0.5;
    cfg.cluster.server_speed_factors = vec![1.0, 1.3, 2.6];
    cfg.cluster.events = vec![
        "at_mb=1 link=1 factor=5.0".to_string(),
        "at_mb=4 server=2 down".to_string(),
        "at_mb=7 server=2 up".to_string(),
    ];
    cfg.validate().unwrap();
    let policy = ClusterPolicy { flat: false, adaptive: true };
    let a = cluster::run_cluster(&cfg, policy, "det").unwrap();
    let b = cluster::run_cluster(&cfg, policy, "det").unwrap();
    assert_eq!(a.logs.len(), b.logs.len());
    for (la, lb) in a.logs.iter().zip(&b.logs) {
        assert_eq!(la.rows.len(), lb.rows.len());
        for (x, y) in la.rows.iter().zip(&lb.rows) {
            assert_eq!(x.clock, y.clock);
            assert_eq!(x.loss, y.loss);
            assert_eq!(x.accuracy, y.accuracy);
            assert_eq!(x.updates, y.updates);
            assert_eq!(x.batch_sizes, y.batch_sizes);
        }
        assert_eq!(la.sync_events, lb.sync_events);
        assert_eq!(la.link_stats, lb.link_stats);
    }
    assert_eq!(a.sync_events, b.sync_events);
    assert_eq!(a.link_stats, b.link_stats);
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.clock, rb.clock);
        assert_eq!(ra.sync_secs, rb.sync_secs);
        assert_eq!(ra.completed, rb.completed);
    }
}

#[test]
fn inert_cluster_block_changes_nothing() {
    // The acceptance gate: with [cluster] absent — or present with
    // servers = 1 — single-server runs are bit-identical.
    let run = |cfg: &Config| -> RunLog {
        let train = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
        let test = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.test_samples, 2);
        let backend = RefBackend;
        let engine =
            Box::new(SimEngine::new(&backend, DevicePool::roster(cfg), CostModel::default()));
        let mut trainer = Trainer::new(cfg.clone(), engine, &backend, TrainerOptions::default());
        trainer.run(&train, &test).unwrap()
    };
    let base = small_cfg(2);
    let plain = run(&base);

    let mut knobs = base.clone();
    knobs.cluster.sync_every = 1;
    knobs.cluster.adaptive = false;
    knobs.cluster.link_gbytes_per_sec = 0.001;
    knobs.cluster.straggler_floor = 0.9;
    knobs.validate().unwrap();
    assert_eq!(knobs.cluster.servers, 1, "the default plane is inert");
    let inert = run(&knobs);

    assert_eq!(plain.rows.len(), inert.rows.len());
    for (x, y) in plain.rows.iter().zip(&inert.rows) {
        assert_eq!(x.clock, y.clock);
        assert_eq!(x.loss, y.loss);
        assert_eq!(x.accuracy, y.accuracy);
        assert_eq!(x.updates, y.updates);
    }
    assert!(plain.sync_events.is_empty() && plain.link_stats.is_empty());
    assert!(inert.sync_events.is_empty() && inert.link_stats.is_empty());
}

#[test]
fn adaptive_cadence_stretches_under_a_throttle_and_loses_no_accuracy() {
    let mut cfg = cluster_cfg(2);
    cfg.cluster.min_sync_every = 1;
    cfg.cluster.max_sync_every = 8;
    cfg.cluster.comm_target = 0.05;
    // A brutal 20x throttle on link 1 from the second sync window on.
    cfg.cluster.events = vec!["at_mb=1 link=1 factor=20.0".to_string()];
    cfg.validate().unwrap();

    let fixed =
        cluster::run_cluster(&cfg, ClusterPolicy { flat: false, adaptive: false }, "fixed")
            .unwrap();
    let adaptive =
        cluster::run_cluster(&cfg, ClusterPolicy { flat: false, adaptive: true }, "adaptive")
            .unwrap();

    // The controller must have reacted: cadence grows past the configured
    // sync_every once the measured sync cost explodes.
    let max_cadence = adaptive.rounds.iter().map(|r| r.sync_every).max().unwrap();
    assert!(
        max_cadence > cfg.cluster.sync_every,
        "adaptive cadence never stretched (max {max_cadence})"
    );
    assert!(
        adaptive.sync_events.iter().any(|e| e.action == "cadence"),
        "cadence moves are logged"
    );
    // Both arms finish all work; adaptive pays for fewer throttled syncs.
    let total = cfg.sgd.num_mega_batches;
    assert!(adaptive.rounds.last().unwrap().completed.iter().all(|&c| c == total));
    assert!(adaptive.syncs < fixed.syncs, "stretching means fewer syncs");
    // And accuracy does not regress relative to the fixed cadence.
    assert!(
        adaptive.mean_final_accuracy() >= fixed.mean_final_accuracy() - 0.02,
        "adaptive {} vs fixed {}",
        adaptive.mean_final_accuracy(),
        fixed.mean_final_accuracy()
    );
}

#[test]
fn rack_loss_stalls_a_server_and_recovery_resyncs_it() {
    let mut cfg = cluster_cfg(2);
    cfg.cluster.events =
        vec!["at_mb=4 server=1 down".to_string(), "at_mb=8 server=1 up".to_string()];
    cfg.validate().unwrap();
    let out = cluster::run_cluster(&cfg, ClusterPolicy { flat: false, adaptive: false }, "rack")
        .unwrap();

    let down =
        out.sync_events.iter().find(|e| e.action == "rack-down").expect("rack went down");
    assert_eq!(down.server, 1);
    let up = out.sync_events.iter().find(|e| e.action == "rack-up").expect("rack came back");
    assert_eq!(up.server, 1);
    assert!(up.at >= down.at);
    // While down, server 1 steps nothing and joins no syncs.
    let stalled: Vec<_> = out.rounds.iter().filter(|r| !r.up[1]).collect();
    assert!(!stalled.is_empty(), "some rounds ran with the rack down");
    for r in &stalled {
        assert!(!r.participants.contains(&1));
    }
    let frozen = stalled[0].completed[1];
    assert!(stalled.iter().all(|r| r.completed[1] == frozen), "no progress while down");
    // Afterwards it catches up and the whole cluster finishes.
    let total = cfg.sgd.num_mega_batches;
    assert!(out.rounds.last().unwrap().completed.iter().all(|&c| c == total));
    assert!(out.logs[1].final_accuracy() > 0.0);
}

#[test]
fn straggler_demotion_fires_below_the_floor_and_only_there() {
    let mut slow = cluster_cfg(2);
    slow.cluster.straggler_floor = 0.5;
    slow.cluster.server_speed_factors = vec![1.0, 3.0]; // rate ratio 1/3 < 0.5
    slow.validate().unwrap();
    let out = cluster::run_cluster(&slow, ClusterPolicy { flat: false, adaptive: false }, "slow")
        .unwrap();
    let demote =
        out.sync_events.iter().find(|e| e.action == "demote").expect("slow server demoted");
    assert_eq!(demote.server, 1);
    // The demoted server lags at least one sync, and its lag is priced
    // into the fabric telemetry as staleness.
    assert!(out.rounds.iter().any(|r| r.completed[1] < r.completed[0]));
    assert!(out.link_stats[1].staleness_mb > 0.0);
    // Everyone still finishes.
    let total = slow.sgd.num_mega_batches;
    assert!(out.rounds.last().unwrap().completed.iter().all(|&c| c == total));

    // With the floor disabled the same cluster never demotes.
    let mut off = slow.clone();
    off.cluster.straggler_floor = 0.0;
    off.validate().unwrap();
    let out =
        cluster::run_cluster(&off, ClusterPolicy { flat: false, adaptive: false }, "off").unwrap();
    assert!(out.sync_events.iter().all(|e| e.action != "demote"));
}

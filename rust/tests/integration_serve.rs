//! Integration over the serving plane (hermetic, reference backend):
//! bit-deterministic virtual-time latency accounting, hot-swap atomicity
//! and request conservation under scripted pool churn, and train-while-
//! serve accuracy tracking with bounded snapshot staleness.

use std::sync::Arc;

use heterosparse::config::{
    Config, DataConfig, DeviceConfig, ModelDims, ServePattern, SgdConfig,
};
use heterosparse::coordinator::backend::RefBackend;
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::data::pipeline::ShardedDataset;
use heterosparse::data::synthetic::Generator;
use heterosparse::harness::{run_single, Backend};
use heterosparse::model::ModelState;
use heterosparse::serve::{replay, ReplayOptions, ServeLog, SnapshotRegistry};

fn serve_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.model = ModelDims { features: 256, hidden: 16, classes: 64, max_nnz: 12, max_labels: 4 };
    cfg.sgd = SgdConfig {
        b_min: 8,
        b_max: 32,
        beta: 8,
        lr_bmax: 0.4,
        mega_batches: 24,
        num_mega_batches: 8,
        initial_batch: 32,
        warmup_mega_batches: 0,
        seed: 3,
        ..Default::default()
    };
    cfg.devices = DeviceConfig {
        count: 4,
        speed_factors: vec![1.0, 1.1, 1.21, 1.32],
        jitter: 0.0,
        nnz_sensitivity: 1.0,
        seed: 11,
    };
    cfg.data =
        DataConfig { train_samples: 2_000, test_samples: 400, avg_nnz: 6.0, ..Default::default() };
    cfg.serve.rate = 5_000.0;
    cfg.serve.duration = 1.0;
    cfg.serve.window = 0.1;
    cfg.validate().unwrap();
    cfg
}

fn corpus(cfg: &Config) -> Arc<ShardedDataset> {
    let ds = Generator::new(&cfg.model, &cfg.data).generate(cfg.data.train_samples, 1);
    Arc::new(ShardedDataset::from_dataset(&ds, cfg.data.pipeline.shard_samples))
}

/// A model whose every parameter equals `v` — a torn read (parameters from
/// two versions mixed) would be non-uniform.
fn constant_model(cfg: &Config, v: f32) -> ModelState {
    let mut m = ModelState::zeros(&cfg.model);
    for seg in m.segments_mut() {
        seg.fill(v);
    }
    m
}

/// Same seed → bit-identical serving runs: every latency percentile, every
/// window row, every batch record.
#[test]
fn virtual_time_serving_is_bit_deterministic() {
    let cfg = serve_cfg();
    let data = corpus(&cfg);
    let run = || -> ServeLog {
        let reg = SnapshotRegistry::new();
        reg.publish(ModelState::init(&cfg.model, 5), Some(0), 0.0);
        replay(
            &cfg,
            data.clone(),
            &reg,
            &RefBackend,
            &ReplayOptions {
                pattern: ServePattern::Bursty,
                duration: cfg.serve.duration,
                follow_clock: false,
                train_log: None,
                name: "det".to_string(),
                obs: heterosparse::obs::ObsHandle::disabled(),
            },
        )
        .unwrap()
    };
    let a = run();
    let b = run();
    assert!(a.total_requests() > 1_000, "trace too small: {}", a.total_requests());
    for p in [50.0, 95.0, 99.0] {
        assert_eq!(
            a.latency_percentile_ms(p).to_bits(),
            b.latency_percentile_ms(p).to_bits(),
            "p{p} must be bit-identical"
        );
    }
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.batches, b.batches);
    assert_eq!(a.rows.len(), b.rows.len());
    for (x, y) in a.rows.iter().zip(&b.rows) {
        assert_eq!(x.p99_ms.to_bits(), y.p99_ms.to_bits(), "window {}", x.window);
        assert_eq!(x.completed, y.completed);
        assert_eq!(x.max_queue_depth, y.max_queue_depth);
    }
    // And the telemetry is non-trivial: positive latencies, served batches.
    assert!(a.latency_percentile_ms(50.0) > 0.0);
    assert!(!a.batches.is_empty());
}

/// Scripted pool churn mid-serve: every admitted request is answered
/// exactly once, no batch routes to the removed device while it is out,
/// and every served snapshot is a fully-published version (hot-swap never
/// exposes a torn model).
#[test]
fn hot_swap_under_churn_conserves_requests_and_serves_whole_versions() {
    let mut cfg = serve_cfg();
    // Window = 0.1s: device 0 leaves at the 3rd boundary, returns at the 7th.
    cfg.serve.events =
        vec!["at_mb=3 remove_id=0".to_string(), "at_mb=7 add_id=0".to_string()];
    cfg.validate().unwrap();
    let data = corpus(&cfg);

    // Three constant-valued versions published at clocks 0.0 / 0.4 / 0.8;
    // follow_clock replays the hot-swaps mid-trace.
    let reg = SnapshotRegistry::new();
    for (i, clock) in [(1usize, 0.0), (2, 0.4), (3, 0.8)] {
        reg.publish(constant_model(&cfg, i as f32 * 0.01), Some(i - 1), clock);
    }
    let log = replay(
        &cfg,
        data.clone(),
        &reg,
        &RefBackend,
        &ReplayOptions {
            pattern: ServePattern::Poisson,
            duration: cfg.serve.duration,
            follow_clock: true,
            train_log: None,
            name: "churn".to_string(),
            obs: heterosparse::obs::ObsHandle::disabled(),
        },
    )
    .unwrap();

    // Request conservation: ids are assigned 0..n in arrival order; every
    // one must complete exactly once, across churn and deadline flushes.
    let mut ids: Vec<u64> = log.requests.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..log.requests.len() as u64).collect::<Vec<_>>());
    assert!(log.requests.iter().all(|r| r.completion > r.arrival));

    // The removed device serves nothing between the boundaries.
    assert_eq!(log.pool_events.len(), 2, "{:?}", log.pool_events);
    assert_eq!(log.pool_events[0].action, "remove");
    assert_eq!(log.pool_events[1].action, "add");
    let (out_at, back_at) = (0.3, 0.7);
    let mut served_while_out = 0usize;
    let mut device0_total = 0usize;
    for b in &log.batches {
        if b.device == 0 {
            device0_total += 1;
            if b.formed_at > out_at + 1e-9 && b.formed_at < back_at - 1e-9 {
                served_while_out += 1;
            }
        }
    }
    assert_eq!(served_while_out, 0, "removed device took new work");
    assert!(device0_total > 0, "device 0 must serve outside the churn window");

    // Hot-swap atomicity: every batch names a published version, versions
    // follow the publish timeline monotonically, and each version's model
    // is internally consistent (all parameters from the same publish).
    assert!(log.batches.iter().all(|b| (1..=3).contains(&b.version)));
    assert!(log.batches.windows(2).all(|w| w[0].version <= w[1].version));
    let versions: std::collections::HashSet<u64> =
        log.batches.iter().map(|b| b.version).collect();
    assert_eq!(versions.len(), 3, "all three snapshots must serve traffic");
    for snap in reg.history() {
        let expect = snap.version as f32 * 0.01;
        assert!(
            snap.model.segments().iter().all(|s| s.iter().all(|&x| x == expect)),
            "version {} model is torn",
            snap.version
        );
    }
}

/// Train-while-serve: the served snapshot's accuracy climbs with the
/// training curve and its staleness stays bounded by `publish_every − 1`.
#[test]
fn train_while_serve_tracks_the_training_curve_with_bounded_staleness() {
    let mut cfg = serve_cfg();
    cfg.serve.publish_every = 2;
    cfg.serve.rate = 30_000.0;
    cfg.validate().unwrap();

    let registry = Arc::new(SnapshotRegistry::new());
    let opts = TrainerOptions { publish: Some(registry.clone()), ..Default::default() };
    let train_log = run_single(&cfg, Backend::Reference, opts).unwrap();
    let final_clock = train_log.rows.last().unwrap().clock;
    // Init + one publish per 2 mega-batches over 8.
    assert_eq!(registry.history().len(), 5);

    let mut tws_cfg = cfg.clone();
    tws_cfg.serve.window = final_clock / 8.0;
    let data = corpus(&cfg);
    let log = replay(
        &tws_cfg,
        data,
        &registry,
        &RefBackend,
        &ReplayOptions {
            pattern: ServePattern::Poisson,
            duration: final_clock,
            follow_clock: true,
            train_log: Some(&train_log),
            name: "tws".to_string(),
            obs: heterosparse::obs::ObsHandle::disabled(),
        },
    )
    .unwrap();
    assert!(log.total_requests() > 500, "trace too small: {}", log.total_requests());

    // Staleness is measured and bounded by the publish cadence.
    let staleness: Vec<usize> = log.batches.iter().filter_map(|b| b.staleness).collect();
    assert!(!staleness.is_empty(), "train-while-serve must measure staleness");
    let max_stale = *staleness.iter().max().unwrap();
    assert!(
        max_stale <= cfg.serve.publish_every - 1,
        "staleness {max_stale} exceeds publish_every-1"
    );

    // The served snapshot's accuracy tracks the training curve: the last
    // window (serving the late model) clearly beats the first (serving the
    // warm-start init model).
    let acc: Vec<f64> = log
        .rows
        .iter()
        .filter(|r| r.completed > 30)
        .map(|r| r.served_accuracy)
        .collect();
    assert!(acc.len() >= 4, "need populated windows, got {}", acc.len());
    let first = *acc.first().unwrap();
    let last = *acc.last().unwrap();
    assert!(
        last > first + 0.05,
        "served accuracy must climb with training: first {first:.4} last {last:.4}"
    );
    // The training-accuracy column mirrors the run log at the window ends.
    let final_row = log.rows.iter().rev().find(|r| r.completed > 0).unwrap();
    assert_eq!(final_row.train_accuracy, train_log.rows.last().unwrap().accuracy);
    // Versions only move forward along the timeline.
    assert!(log.batches.windows(2).all(|w| w[0].version <= w[1].version));
}

//! Offline drop-in subset of the `anyhow` API (this tree vendors its own
//! copy so the workspace builds without a crates.io registry).
//!
//! Implements the pieces the crate actually uses: [`Error`], [`Result`],
//! the [`Context`] extension trait for `Result`/`Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Like upstream anyhow, [`Error`]
//! deliberately does **not** implement `std::error::Error`, which is what
//! makes the blanket `From<E: std::error::Error>` conversion possible.

use std::fmt;

/// Error type: an ordered context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std<E: std::error::Error>(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(cause) = src {
            chain.push(cause.to_string());
            src = cause.source();
        }
        Error { chain }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first (upstream: `chain()`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost error in the chain (upstream: `root_cause()`).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` flattens the whole chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(err)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to errors (and `None`s).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from_std(e).wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from_std(e).wrap(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chain_formats() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.context("value required").unwrap_err();
        assert_eq!(e.to_string(), "value required");

        fn fails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert!(fails(5).is_ok());
        assert_eq!(fails(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(fails(99).unwrap_err().to_string(), "x too big: 99");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }

    #[test]
    fn nested_context_stacks() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("inner").map_err(|e| e.wrap("outer")).unwrap_err();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "inner", "missing file"]);
    }
}

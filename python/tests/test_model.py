"""L2 model tests: manual backprop vs jax.grad, mask semantics, training."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

DIMS = dict(F=128, H=16, C=64, K=8, L=4)


def make_batch(b, rng, dims=DIMS, valid=None):
    idx = rng.integers(0, dims["F"], (b, dims["K"])).astype(np.int32)
    val = np.abs(rng.normal(size=(b, dims["K"]))).astype(np.float32)
    nlab = rng.integers(1, dims["L"] + 1, b)
    lab = np.zeros((b, dims["L"]), np.int32)
    lab_w = np.zeros((b, dims["L"]), np.float32)
    for i in range(b):
        lab[i, : nlab[i]] = rng.integers(0, dims["C"], nlab[i])
        lab_w[i, : nlab[i]] = 1.0 / nlab[i]
    smask = np.ones(b, np.float32)
    if valid is not None:
        smask[valid:] = 0.0
        lab_w[valid:] = 0.0
    return idx, val, lab, lab_w, smask


def make_params(rng, dims=DIMS, scale=0.05):
    w1 = (rng.normal(size=(dims["F"], dims["H"])) * scale).astype(np.float32)
    b1 = np.zeros(dims["H"], np.float32)
    w2 = (rng.normal(size=(dims["H"], dims["C"])) * scale).astype(np.float32)
    b2 = np.zeros(dims["C"], np.float32)
    return w1, b1, w2, b2


def ref_loss(w1, b1, w2, b2, idx, val, lab, lab_w, smask):
    """Differentiable pure-jnp loss (no Pallas) for jax.grad cross-check."""
    a = ref.sparse_embed_ref(idx, val, w1) + b1[None, :]
    h = jax.nn.relu(a)
    logits = h @ w2 + b2[None, :]
    lse = ref.logsumexp_ref(logits)
    picked = jnp.take_along_axis(logits, lab, axis=1)
    pos = jnp.sum(lab_w * picked, axis=1)
    return jnp.sum(smask * (lse - pos)) / jnp.maximum(jnp.sum(smask), 1.0)


def test_manual_backprop_matches_jax_grad():
    rng = np.random.default_rng(0)
    w1, b1, w2, b2 = make_params(rng)
    idx, val, lab, lab_w, smask = make_batch(12, rng)
    lr = 0.1

    nw1, nb1, nw2, nb2, loss = model.sgd_step(
        w1, b1, w2, b2, idx, val, lab, lab_w, smask, jnp.float32(lr)
    )
    g = jax.grad(ref_loss, argnums=(0, 1, 2, 3))(
        jnp.array(w1), jnp.array(b1), jnp.array(w2), jnp.array(b2),
        jnp.array(idx), jnp.array(val), jnp.array(lab), jnp.array(lab_w), jnp.array(smask),
    )
    expect = [w1 - lr * np.asarray(g[0]), b1 - lr * np.asarray(g[1]),
              w2 - lr * np.asarray(g[2]), b2 - lr * np.asarray(g[3])]
    for got, exp in zip([nw1, nb1, nw2, nb2], expect):
        np.testing.assert_allclose(np.asarray(got), exp, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(
        float(loss),
        float(ref_loss(w1, b1, w2, b2, idx, val, lab, lab_w, smask)),
        rtol=1e-5,
    )


def test_masked_samples_do_not_contribute():
    """Bucket padding (smask=0) must leave the update identical."""
    rng = np.random.default_rng(1)
    w1, b1, w2, b2 = make_params(rng)
    idx, val, lab, lab_w, smask = make_batch(8, rng, valid=5)
    # Same first 5 samples, no padding.
    out_padded = model.sgd_step(w1, b1, w2, b2, idx, val, lab, lab_w, smask, jnp.float32(0.1))
    out_exact = model.sgd_step(
        w1, b1, w2, b2, idx[:5], val[:5], lab[:5], lab_w[:5], np.ones(5, np.float32),
        jnp.float32(0.1),
    )
    # Padded rows still gather/scatter W1 rows, but with zero cotangent —
    # except val is nonzero for pad rows here, so zero smask must kill them
    # through dlogits. Compare parameters.
    for got, exp in zip(out_padded[:4], out_exact[:4]):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(float(out_padded[4]), float(out_exact[4]), rtol=1e-5)


def test_loss_decreases_on_fixed_batch():
    rng = np.random.default_rng(2)
    w1, b1, w2, b2 = make_params(rng)
    idx, val, lab, lab_w, smask = make_batch(16, rng)
    step = jax.jit(model.sgd_step)
    losses = []
    for _ in range(30):
        w1, b1, w2, b2, loss = step(w1, b1, w2, b2, idx, val, lab, lab_w, smask, jnp.float32(0.5))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses[:3] + losses[-3:]


def test_eval_batch_predicts_argmax():
    rng = np.random.default_rng(3)
    w1, b1, w2, b2 = make_params(rng)
    idx, val, lab, lab_w, smask = make_batch(6, rng)
    preds = np.asarray(model.eval_batch(w1, b1, w2, b2, idx, val))
    _, _, logits = model.forward(w1, b1, w2, b2, idx, val)
    np.testing.assert_array_equal(preds, np.argmax(np.asarray(logits), axis=1))
    assert preds.dtype == np.int32


def test_lr_zero_is_identity():
    rng = np.random.default_rng(4)
    w1, b1, w2, b2 = make_params(rng)
    idx, val, lab, lab_w, smask = make_batch(4, rng)
    out = model.sgd_step(w1, b1, w2, b2, idx, val, lab, lab_w, smask, jnp.float32(0.0))
    for got, exp in zip(out[:4], [w1, b1, w2, b2]):
        np.testing.assert_array_equal(np.asarray(got), exp)

"""AOT pipeline tests: bucket grid, HLO-text lowering, manifest round-trip."""

import json
import os

import pytest

from compile import aot


def test_bucket_grid_default_paper_geometry():
    # Paper: b_min = b_max/8, beta = b_min/2 -> 15 grid points.
    grid = aot.bucket_grid(16, 128, 8)
    assert grid[0] == 16 and grid[-1] == 128 and len(grid) == 15
    assert all(b - a == 8 for a, b in zip(grid, grid[1:]))


def test_bucket_grid_rejects_misaligned():
    with pytest.raises(AssertionError):
        aot.bucket_grid(16, 100, 8)


SMALL = dict(features=256, hidden=16, classes=64, max_nnz=8, max_labels=4)


def test_step_lowering_produces_hlo_text():
    text = aot.to_hlo_text(aot.lower_step(SMALL, 8))
    assert text.startswith("HloModule"), text[:80]
    # Tuple-return convention the Rust loader depends on.
    assert "ROOT" in text


def test_eval_lowering_produces_hlo_text():
    text = aot.to_hlo_text(aot.lower_eval(SMALL, 16))
    assert text.startswith("HloModule")


def test_build_writes_consistent_manifest(tmp_path):
    args = aot.parser().parse_args(
        [
            "--out-dir", str(tmp_path),
            "--features", "256", "--hidden", "16", "--classes", "64",
            "--max-nnz", "8", "--max-labels", "4",
            "--b-min", "8", "--b-max", "16", "--beta", "8",
            "--eval-batch", "16",
        ]
    )
    manifest = aot.build(args)
    on_disk = json.loads((tmp_path / "manifest.json").read_text())
    assert on_disk["config_hash"] == manifest["config_hash"]
    assert on_disk["buckets"] == [8, 16]
    assert on_disk["step_inputs"][0] == "w1" and on_disk["step_inputs"][-1] == "lr"
    for name in on_disk["files"]["step"].values():
        assert (tmp_path / name).exists()
    assert (tmp_path / on_disk["files"]["eval"]).exists()
    # Every HLO file parses as text-format HLO (spot check header).
    for f in tmp_path.glob("*.hlo.txt"):
        assert f.read_text().startswith("HloModule")

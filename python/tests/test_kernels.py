"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/values; deterministic cases cover the edge
conditions the AOT pipeline relies on (padding semantics, duplicate indices,
single-tile and multi-tile class dims).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sparse_matmul import sparse_embed
from compile.kernels.xent import tiled_logsumexp


def _allclose(a, b, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# sparse_embed (gather-SpMM)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 12),
    k=st.integers(1, 24),
    f=st.integers(2, 200),
    h=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparse_embed_matches_ref(b, k, f, h, seed):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, f, (b, k)).astype(np.int32)
    val = rng.normal(size=(b, k)).astype(np.float32)
    w1 = rng.normal(size=(f, h)).astype(np.float32)
    out = sparse_embed(jnp.array(idx), jnp.array(val), jnp.array(w1))
    _allclose(out, ref.sparse_embed_ref(jnp.array(idx), jnp.array(val), jnp.array(w1)))


def test_sparse_embed_padding_is_inert():
    """val==0 rows contribute nothing regardless of the (arbitrary) pad index."""
    rng = np.random.default_rng(7)
    f, h = 64, 16
    w1 = rng.normal(size=(f, h)).astype(np.float32)
    idx = np.array([[3, 0, 0, 0], [5, 9, 0, 0]], dtype=np.int32)
    val = np.array([[2.0, 0.0, 0.0, 0.0], [1.0, -1.0, 0.0, 0.0]], dtype=np.float32)
    out = np.asarray(sparse_embed(jnp.array(idx), jnp.array(val), jnp.array(w1)))
    _allclose(out[0], 2.0 * w1[3])
    _allclose(out[1], w1[5] - w1[9])


def test_sparse_embed_duplicate_indices_accumulate():
    rng = np.random.default_rng(8)
    w1 = rng.normal(size=(32, 8)).astype(np.float32)
    idx = np.array([[4, 4, 4]], dtype=np.int32)
    val = np.array([[1.0, 2.0, 3.0]], dtype=np.float32)
    out = np.asarray(sparse_embed(jnp.array(idx), jnp.array(val), jnp.array(w1)))
    _allclose(out[0], 6.0 * w1[4])


def test_sparse_embed_all_padding_is_zero():
    w1 = np.ones((16, 4), dtype=np.float32)
    idx = np.zeros((3, 5), dtype=np.int32)
    val = np.zeros((3, 5), dtype=np.float32)
    out = np.asarray(sparse_embed(jnp.array(idx), jnp.array(val), jnp.array(w1)))
    assert np.all(out == 0.0)


# ---------------------------------------------------------------------------
# tiled_logsumexp (online softmax)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    bt=st.sampled_from([1, 2, 4, 8]),
    nb=st.integers(1, 4),
    ct=st.sampled_from([8, 16, 64]),
    nc=st.integers(1, 6),
    scale=st.floats(0.01, 50.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_logsumexp_matches_ref(bt, nb, ct, nc, scale, seed):
    rng = np.random.default_rng(seed)
    b, c = bt * nb, ct * nc
    logits = (rng.normal(size=(b, c)) * scale).astype(np.float32)
    out = tiled_logsumexp(jnp.array(logits), class_tile=ct, batch_tile=bt)
    _allclose(out, ref.logsumexp_ref(jnp.array(logits)), rtol=1e-4, atol=1e-4)


def test_tiled_logsumexp_extreme_values_stable():
    """Online rescaling must not overflow even with large logits."""
    logits = np.array(
        [[80.0, -80.0, 79.0, 0.0], [-200.0, -201.0, -199.0, -200.5]],
        dtype=np.float32,
    )
    out = np.asarray(tiled_logsumexp(jnp.array(logits), class_tile=2, batch_tile=1))
    expect = np.asarray(ref.logsumexp_ref(jnp.array(logits)))
    assert np.all(np.isfinite(out))
    _allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_tiled_logsumexp_single_tile():
    rng = np.random.default_rng(3)
    logits = rng.normal(size=(4, 32)).astype(np.float32)
    out = tiled_logsumexp(jnp.array(logits), class_tile=32, batch_tile=4)
    _allclose(out, ref.logsumexp_ref(jnp.array(logits)))


def test_tiled_logsumexp_nondivisible_tile_snaps_down():
    """Tile hints that don't divide the shape are snapped to a divisor."""
    rng = np.random.default_rng(5)
    logits = rng.normal(size=(5, 30)).astype(np.float32)
    out = tiled_logsumexp(jnp.array(logits), class_tile=8, batch_tile=4)
    _allclose(out, ref.logsumexp_ref(jnp.array(logits)), rtol=1e-5, atol=1e-5)

"""Layer-1 Pallas kernel: sparse gather-SpMM for the MLP input layer.

The paper's hot spot is the sparse input layer computed with cuSPARSE SpMM on
V100s. On TPU-shaped Pallas the same insight — the input layer is *gather
bound*, not FLOP bound — maps to: one grid program per batch tile, the padded
(index, value) lists resident in VMEM, rows of W1 streamed from HBM with
scalar dynamic slices, and a VMEM accumulator tile. ``interpret=True`` is
mandatory here: it lowers the kernel to plain HLO ops the CPU PJRT client can
run (real TPU lowering emits a Mosaic custom-call). See DESIGN.md
§Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sparse_embed_kernel(idx_ref, val_ref, w1_ref, out_ref, *, max_nnz: int):
    """One grid program computes the input-layer activation for one sample.

    idx_ref: int32[1, K] VMEM — padded feature indices for this sample.
    val_ref: f32[1, K]  VMEM — matching values (0.0 on padding).
    w1_ref:  f32[F, H]       — full first-layer weights (streamed by row).
    out_ref: f32[1, H]  VMEM — accumulator / output tile.
    """
    hidden = out_ref.shape[1]

    def body(k, acc):
        i = idx_ref[0, k]
        v = val_ref[0, k]
        # Dynamic single-row gather: the HBM->VMEM stream. On real TPU this
        # is the analogue of the paper's coalesced row loads.
        row = w1_ref[pl.dslice(i, 1), :]  # (1, H)
        return acc + v * row.reshape((hidden,))

    acc = jax.lax.fori_loop(0, max_nnz, body, jnp.zeros((hidden,), jnp.float32))
    out_ref[0, :] = acc


def sparse_embed(idx: jnp.ndarray, val: jnp.ndarray, w1: jnp.ndarray) -> jnp.ndarray:
    """Pallas sparse gather-SpMM: ``out[i] = sum_k val[i,k] * w1[idx[i,k], :]``.

    Shapes: idx int32[B, K], val f32[B, K], w1 f32[F, H] -> f32[B, H].
    Matches ``ref.sparse_embed_ref`` (tested in python/tests/test_kernels.py).
    """
    batch, max_nnz = idx.shape
    features, hidden = w1.shape
    kernel = functools.partial(_sparse_embed_kernel, max_nnz=max_nnz)
    return pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, max_nnz), lambda b: (b, 0)),
            pl.BlockSpec((1, max_nnz), lambda b: (b, 0)),
            # W1 is not blocked: every program may touch any row. interpret
            # mode holds it in host memory; the TPU schedule would pin it in
            # HBM (memory_space=ANY) and rely on the row gathers above.
            pl.BlockSpec((features, hidden), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hidden), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, hidden), jnp.float32),
        interpret=True,
    )(idx, val, w1)

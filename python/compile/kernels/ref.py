"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the Pallas kernels are tested against (pytest +
hypothesis sweeps in ``python/tests/``). They are also the executable
specification of the math the Rust reference MLP (``rust/src/model/reference.rs``)
must match at f32 tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def sparse_embed_ref(idx: jnp.ndarray, val: jnp.ndarray, w1: jnp.ndarray) -> jnp.ndarray:
    """Sparse gather-SpMM: ``out[i] = sum_k val[i,k] * w1[idx[i,k], :]``.

    Args:
      idx: int32[B, K] padded per-sample feature indices (pad rows -> index 0).
      val: f32[B, K] feature values; padding entries MUST be 0.0 so they
        contribute nothing regardless of the pad index.
      w1:  f32[F, H] input embedding / first-layer weight matrix.

    Returns:
      f32[B, H] — the sparse input-layer pre-activation (before bias).
    """
    rows = w1[idx]  # (B, K, H)
    return jnp.einsum("bk,bkh->bh", val, rows)


def logsumexp_ref(logits: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable row-wise logsumexp over the class dimension.

    Args:
      logits: f32[B, C].
    Returns:
      f32[B] — ``log(sum_c exp(logits[b, c]))``.
    """
    m = jnp.max(logits, axis=-1)
    return m + jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))

"""Layer-1 Pallas kernel: tiled online-softmax logsumexp over the class dim.

The output layer of the XML MLP spans up to hundreds of thousands of classes;
the paper fuses the softmax/cross-entropy element-wise kernels to avoid
materializing intermediates (HeteroGPU "kernel fusion"). The TPU-shaped
equivalent is a single-pass *online softmax*: the class dimension is tiled
into VMEM-sized blocks and a running (max, scaled-sum) pair is carried across
tiles, so the full logits row never needs to be resident more than one tile
at a time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _logsumexp_kernel(logits_ref, out_ref, *, classes: int, tile: int):
    """Row-block online logsumexp.

    logits_ref: f32[Bt, C] — logits for a tile of samples.
    out_ref:    f32[Bt]    — per-sample logsumexp.
    """
    bt = logits_ref.shape[0]
    n_tiles = classes // tile

    def body(j, carry):
        m, s = carry  # running max (Bt,), running sum of exp(x - m) (Bt,)
        blk = logits_ref[:, pl.dslice(j * tile, tile)]  # (Bt, tile)
        bm = jnp.max(blk, axis=1)
        new_m = jnp.maximum(m, bm)
        # Rescale the old sum to the new max, then add this tile's mass.
        s = s * jnp.exp(m - new_m) + jnp.sum(jnp.exp(blk - new_m[:, None]), axis=1)
        return new_m, s

    init = (jnp.full((bt,), -jnp.inf, jnp.float32), jnp.zeros((bt,), jnp.float32))
    m, s = jax.lax.fori_loop(0, n_tiles, body, init)
    out_ref[...] = m + jnp.log(s)


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def tiled_logsumexp(logits: jnp.ndarray, *, class_tile: int = 512, batch_tile: int = 8) -> jnp.ndarray:
    """Pallas online-softmax logsumexp: f32[B, C] -> f32[B].

    ``class_tile``/``batch_tile`` are upper bounds; they are snapped down to
    the largest divisor of C/B so any shape is accepted. Matches
    ``ref.logsumexp_ref``.
    """
    batch, classes = logits.shape
    class_tile = _largest_divisor_leq(classes, class_tile)
    batch_tile = _largest_divisor_leq(batch, batch_tile)
    kernel = functools.partial(_logsumexp_kernel, classes=classes, tile=class_tile)
    return pl.pallas_call(
        kernel,
        grid=(batch // batch_tile,),
        in_specs=[pl.BlockSpec((batch_tile, classes), lambda b: (b, 0))],
        out_specs=pl.BlockSpec((batch_tile,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        interpret=True,
    )(logits)

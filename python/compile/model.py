"""Layer-2: the paper's model — a 3-layer sparse MLP for XML classification.

This is the SLIDE testbed architecture the paper trains (Section 5.1):
sparse input features -> H-unit ReLU hidden layer -> C-way softmax with
multi-label cross-entropy. The forward pass calls the Layer-1 Pallas kernels
(``kernels.sparse_matmul.sparse_embed`` and ``kernels.xent.tiled_logsumexp``);
the backward pass is written out *manually* so that

  1. the exact same math is mirrored in the Rust reference implementation
     (``rust/src/model/reference.rs``) used to cross-check the AOT artifacts,
  2. the W1 update stays *sparse*: the gradient only touches the rows gathered
     in the forward pass, so the SGD update is a scatter-add rather than a
     dense (F, H) materialization — the same optimization the paper gets from
     cuSPARSE.

Batch encoding (all shapes static; see DESIGN.md on batch-size buckets):
  idx    int32[B, K]  padded per-sample feature indices (pad -> 0)
  val    f32[B, K]    feature values, 0.0 on padding
  lab    int32[B, L]  padded per-sample label indices (pad -> 0)
  lab_w  f32[B, L]    label weights, sum to 1 per valid sample, 0.0 on padding
  smask  f32[B]       1.0 for real samples, 0.0 for bucket padding
Multi-hot labels are normalized (y / |y|) exactly as in SLIDE.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.sparse_matmul import sparse_embed
from .kernels.xent import tiled_logsumexp


def forward(w1, b1, w2, b2, idx, val):
    """Forward pass to logits. Returns (pre_act, hidden, logits)."""
    a = sparse_embed(idx, val, w1) + b1[None, :]  # (B, H) Pallas gather-SpMM
    h = jax.nn.relu(a)
    logits = h @ w2 + b2[None, :]  # (B, C) — the MXU-shaped dense layer
    return a, h, logits


def loss_from_logits(logits, lab, lab_w, smask):
    """Mean multi-label softmax cross-entropy over valid samples.

    loss_i = logsumexp(logits_i) - sum_l lab_w[i,l] * logits[i, lab[i,l]]
    """
    lse = tiled_logsumexp(logits)  # (B,) Pallas online softmax
    picked = jnp.take_along_axis(logits, lab, axis=1)  # (B, L)
    pos = jnp.sum(lab_w * picked, axis=1)  # (B,)
    per_sample = lse - pos
    denom = jnp.maximum(jnp.sum(smask), 1.0)
    return jnp.sum(smask * per_sample) / denom, lse


def sgd_step(w1, b1, w2, b2, idx, val, lab, lab_w, smask, lr):
    """One SGD step: returns (w1', b1', w2', b2', loss).

    Manual backprop (see module docstring). The W1 update is a sparse
    scatter-add over only the gathered rows.
    """
    w1, b1, w2, b2 = map(jnp.asarray, (w1, b1, w2, b2))
    idx, val, lab, lab_w, smask = map(jnp.asarray, (idx, val, lab, lab_w, smask))
    batch = idx.shape[0]

    a, h, logits = forward(w1, b1, w2, b2, idx, val)
    loss, lse = loss_from_logits(logits, lab, lab_w, smask)

    denom = jnp.maximum(jnp.sum(smask), 1.0)
    scale = (smask / denom)[:, None]  # (B, 1)

    # dL/dlogits = (softmax(logits) - y) * smask / n, with y the normalized
    # multi-hot label distribution — applied sparsely at the label positions.
    probs = jnp.exp(logits - lse[:, None])  # (B, C)
    dlogits = probs * scale
    rows = jnp.repeat(jnp.arange(batch)[:, None], lab.shape[1], axis=1)  # (B, L)
    dlogits = dlogits.at[rows, lab].add(-lab_w * scale)

    # Output layer.
    dw2 = h.T @ dlogits  # (H, C)
    db2 = jnp.sum(dlogits, axis=0)  # (C,)
    dh = dlogits @ w2.T  # (B, H)

    # Hidden layer (ReLU).
    da = dh * (a > 0.0)  # (B, H)
    db1 = jnp.sum(da, axis=0)  # (H,)

    # Sparse input layer: dW1[idx[i,k]] += val[i,k] * da[i]; fold the SGD
    # update into a single scatter so no dense (F, H) gradient exists.
    flat_idx = idx.reshape(-1)  # (B*K,)
    contrib = (val[:, :, None] * da[:, None, :]).reshape(-1, da.shape[1])  # (B*K, H)
    new_w1 = w1.at[flat_idx].add(-lr * contrib)

    new_b1 = b1 - lr * db1
    new_w2 = w2 - lr * dw2
    new_b2 = b2 - lr * db2
    return new_w1, new_b1, new_w2, new_b2, loss


def eval_batch(w1, b1, w2, b2, idx, val):
    """Inference for test-set evaluation: top-1 class per sample.

    Returns int32[B] predicted class ids; the Rust side checks membership in
    each sample's label set (P@1, the paper's top-1 accuracy).
    """
    _, _, logits = forward(w1, b1, w2, b2, idx, val)
    return jnp.argmax(logits, axis=1).astype(jnp.int32)

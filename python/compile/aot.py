"""AOT pipeline: lower the L2 model to HLO *text* artifacts + manifest.

Run once at build time (``make artifacts``); the Rust coordinator loads the
HLO text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. HLO text — NOT ``.serialize()`` — is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids),
while the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Adaptive batch sizes vs static AOT shapes: Algorithm 1's batch sizes are
quantized to the grid {b_min, b_min+beta, ..., b_max} and one step executable
is emitted per grid point ("bucket"). Partial batches are padded up to the
nearest bucket with smask=0 rows. manifest.json records dims, buckets and
file names; the Rust runtime validates its config against it.

Usage: python -m compile.aot --out-dir ../artifacts [--features F ...]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def bucket_grid(b_min: int, b_max: int, beta: int) -> list[int]:
    """The batch-size grid Algorithm 1 quantizes to."""
    assert b_min >= 1 and b_max >= b_min and beta >= 1
    assert (b_max - b_min) % beta == 0, "b_max - b_min must be a multiple of beta"
    return list(range(b_min, b_max + 1, beta))


def lower_step(dims: dict, batch: int):
    f32 = jnp.float32
    i32 = jnp.int32
    F, H, C = dims["features"], dims["hidden"], dims["classes"]
    K, L = dims["max_nnz"], dims["max_labels"]
    spec = jax.ShapeDtypeStruct
    return jax.jit(model.sgd_step).lower(
        spec((F, H), f32),   # w1
        spec((H,), f32),     # b1
        spec((H, C), f32),   # w2
        spec((C,), f32),     # b2
        spec((batch, K), i32),  # idx
        spec((batch, K), f32),  # val
        spec((batch, L), i32),  # lab
        spec((batch, L), f32),  # lab_w
        spec((batch,), f32),    # smask
        spec((), f32),          # lr
    )


def lower_eval(dims: dict, batch: int):
    f32 = jnp.float32
    i32 = jnp.int32
    F, H, C = dims["features"], dims["hidden"], dims["classes"]
    K = dims["max_nnz"]
    spec = jax.ShapeDtypeStruct
    return jax.jit(model.eval_batch).lower(
        spec((F, H), f32),
        spec((H,), f32),
        spec((H, C), f32),
        spec((C,), f32),
        spec((batch, K), i32),
        spec((batch, K), f32),
    )


def build(args: argparse.Namespace) -> dict:
    dims = {
        "features": args.features,
        "hidden": args.hidden,
        "classes": args.classes,
        "max_nnz": args.max_nnz,
        "max_labels": args.max_labels,
    }
    buckets = bucket_grid(args.b_min, args.b_max, args.beta)
    os.makedirs(args.out_dir, exist_ok=True)

    files: dict = {"step": {}, "eval": "eval.hlo.txt"}
    for b in buckets:
        name = f"step_b{b}.hlo.txt"
        text = to_hlo_text(lower_step(dims, b))
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        files["step"][str(b)] = name
        print(f"  step bucket b={b:<5d} -> {name} ({len(text)} chars)", flush=True)

    text = to_hlo_text(lower_eval(dims, args.eval_batch))
    with open(os.path.join(args.out_dir, files["eval"]), "w") as f:
        f.write(text)
    print(f"  eval batch  b={args.eval_batch:<5d} -> {files['eval']} ({len(text)} chars)")

    manifest = {
        "version": MANIFEST_VERSION,
        "dims": dims,
        "buckets": buckets,
        "b_min": args.b_min,
        "b_max": args.b_max,
        "beta": args.beta,
        "eval_batch": args.eval_batch,
        "files": files,
        # Step executable I/O contract, in order. The Rust runtime asserts
        # this layout at load time.
        "step_inputs": ["w1", "b1", "w2", "b2", "idx", "val", "lab", "lab_w", "smask", "lr"],
        "step_outputs": ["w1", "b1", "w2", "b2", "loss"],
        "eval_inputs": ["w1", "b1", "w2", "b2", "idx", "val"],
        "eval_outputs": ["preds"],
        "jax_version": jax.__version__,
    }
    manifest["config_hash"] = hashlib.sha256(
        json.dumps({k: manifest[k] for k in ("dims", "buckets", "eval_batch")}, sort_keys=True).encode()
    ).hexdigest()[:16]
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    # Default ("small") profile — must match rust/src/config defaults.
    p.add_argument("--features", type=int, default=8192)
    p.add_argument("--hidden", type=int, default=64)
    p.add_argument("--classes", type=int, default=1024)
    p.add_argument("--max-nnz", type=int, default=32)
    p.add_argument("--max-labels", type=int, default=8)
    p.add_argument("--b-min", type=int, default=16)
    p.add_argument("--b-max", type=int, default=128)
    p.add_argument("--beta", type=int, default=8)
    p.add_argument("--eval-batch", type=int, default=256)
    return p


def main(argv=None) -> None:
    args = parser().parse_args(argv)
    print(f"[aot] lowering model to {args.out_dir} (jax {jax.__version__})")
    manifest = build(args)
    print(f"[aot] wrote manifest config_hash={manifest['config_hash']} "
          f"buckets={len(manifest['buckets'])}")


if __name__ == "__main__":
    main()

//! The adaptive-sparsity compute lever: when a device throttles past what
//! batch scaling can absorb, the scheduler shrinks its LSH active-class
//! ratio instead of letting it straggle.
//!
//! Four homogeneous simulated devices train adaptive SGD with the
//! calibration plane and the `[slide]` lever both on. A scripted trace
//! throttles device 0 to 10× a third of the way in — so hard that the
//! equal-time batch size falls below `b_min` and the batch knob alone
//! cannot rebalance. The printed trace shows the joint re-targeting: the
//! batch grid shrinks to the floor AND the throttled device walks down
//! the sparsity ratio ladder, its per-step active-class count dropping
//! with it, while per-device update counts stay near-equal.
//!
//! ```bash
//! cargo run --release --example adaptive_sparsity
//! ```

use heterosparse::config::Config;
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::harness::{run_single, Backend};
use heterosparse::runtime::CostModel;
use heterosparse::tuning::multiplier_at;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.data.train_samples = 8_000;
    cfg.data.test_samples = 1_000;
    cfg.sgd.lr_bmax = 0.3;
    cfg.sgd.num_mega_batches = 12;
    cfg.devices.speed_factors = vec![1.0; 4];
    cfg.devices.jitter = 0.0; // keep the printed trace crisp
    let throttle_at = 4;
    let recover_at = 8;
    cfg.calibration.enabled = true;
    cfg.calibration.step_obs = 1;
    cfg.calibration.events = vec![
        format!("at_mb={throttle_at} device=0 factor=10.0 ramp=1"),
        format!("at_mb={recover_at} device=0 factor=1.0 ramp=1"),
    ];
    cfg.slide.adaptive = true; // arm the sparsity lever
    cfg.validate()?;
    let trace = cfg.calibration.parsed_events()?;

    // The lever's cost curve: predicted per-step time on the throttled
    // device down the configured ratio ladder.
    let cost = CostModel::default();
    let b = cfg.sgd.b_max;
    let nnz = (cfg.data.avg_nnz * b as f64) as usize;
    println!("per-step cost on the 10x-throttled device, down the ratio ladder:");
    for r in cfg.slide.ratio_ladder() {
        let ms = 10.0 * cost.step_time_parts_at(b, nnz, r) * 1e3;
        println!("  ratio {r:>4.2}  ->  {ms:>7.3} ms");
    }
    println!();

    let log = run_single(&cfg, Backend::Auto, TrainerOptions::default())?;

    println!("mega-batch  drift d0  batch grid          ratio d0  act d0  updates             P@1");
    for r in &log.rows {
        println!(
            "{:>10}  {:>8.2}  {:<18}  {:>8.2}  {:>6.0}  {:<18}  {:.4}",
            r.mega_batch,
            multiplier_at(&trace, 0, r.mega_batch),
            format!("{:?}", r.batch_sizes),
            r.sparsity_ratio[0],
            r.active_classes[0],
            format!("{:?}", r.updates),
            r.accuracy,
        );
    }
    println!(
        "\nrun update balance (max/min per-device updates, 1.0 = ideal): {:.2}",
        log.update_balance()
    );
    let clock = log.rows.last().map(|r| r.clock).unwrap_or(0.0);
    println!("final P@1 {:.4} over {clock:.2}s of virtual training", log.final_accuracy());
    Ok(())
}

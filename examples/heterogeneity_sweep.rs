//! Heterogeneity sweep: how much does Adaptive SGD buy as the device fleet
//! gets more skewed? (The workload the paper's introduction motivates.)
//!
//! Sweeps the fastest↔slowest speed gap from 0% to 60% and compares
//! Adaptive vs Elastic time-to-accuracy on each fleet. Expectation: the two
//! coincide on a homogeneous fleet and Adaptive pulls ahead as skew grows.

use heterosparse::config::{Config, Strategy};
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::harness::{run_single, Backend};
use heterosparse::util::bench::Table;

fn config(gap: f64, strategy: Strategy) -> Config {
    let mut cfg = Config::default();
    cfg.data.train_samples = 10_000;
    cfg.data.test_samples = 1_200;
    cfg.sgd.lr_bmax = 0.3;
    cfg.sgd.num_mega_batches = 10;
    cfg.devices.count = 4;
    cfg.devices.speed_factors = (0..4).map(|i| 1.0 + gap * i as f64 / 3.0).collect();
    cfg.strategy.kind = strategy;
    cfg.validate().unwrap();
    cfg
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&[
        "speed gap",
        "adaptive best P@1",
        "elastic best P@1",
        "adaptive clock (s)",
        "elastic clock (s)",
        "clock ratio",
    ]);
    for gap in [0.0, 0.15, 0.32, 0.60] {
        let a = run_single(&config(gap, Strategy::Adaptive), Backend::Auto, TrainerOptions::default())?;
        let e = run_single(&config(gap, Strategy::Elastic), Backend::Auto, TrainerOptions::default())?;
        let a_clock = a.rows.last().unwrap().clock;
        let e_clock = e.rows.last().unwrap().clock;
        table.row(&[
            format!("{:.0}%", gap * 100.0),
            format!("{:.4}", a.best_accuracy()),
            format!("{:.4}", e.best_accuracy()),
            format!("{a_clock:.2}"),
            format!("{e_clock:.2}"),
            format!("{:.2}x", e_clock / a_clock),
        ]);
    }
    table.print("Adaptive vs Elastic under increasing heterogeneity (same sample budget)");
    println!("\n(clock ratio > 1 means Elastic burned more time on the same budget — straggler cost)");
    Ok(())
}

//! §Perf breakdown probe (EXPERIMENTS.md §Perf): isolates literal-creation
//! cost from PJRT execute cost on the step hot path. Requires the `pjrt`
//! cargo feature (the `xla` crate) plus `make artifacts`; without the
//! feature it prints a skip message so the workspace builds offline. The
//! `vec1+reshape` row is kept as the before-measurement of optimization #1.

fn main() {
    run();
}

#[cfg(not(feature = "pjrt"))]
fn run() {
    eprintln!(
        "perf_probe skipped: build with `--features pjrt` (needs the xla crate) and run \
         `make artifacts` first"
    );
}

#[cfg(feature = "pjrt")]
fn run() {
    use heterosparse::config::Config;
    use heterosparse::data::batcher::Batcher;
    use heterosparse::data::synthetic::Generator;
    use heterosparse::model::ModelState;
    use heterosparse::runtime::Runtime;
    use std::time::Instant;

    let cfg = Config::default();
    let rt = Runtime::load(std::path::Path::new("artifacts")).unwrap();
    let train = Generator::new(&cfg.model, &cfg.data).generate(2000, 1);
    let mut b = Batcher::new(&train, &cfg.model, 1);
    let batch = b.next_batch(128, 128);
    let mut m = ModelState::init(&cfg.model, 7);
    rt.step(&mut m, &batch, 0.01).unwrap();

    // Breakdown: literal creation cost
    let t0 = Instant::now();
    let n = 200;
    for _ in 0..n {
        let l = xla::Literal::vec1(&m.w1).reshape(&[8192, 64]).unwrap();
        std::hint::black_box(l);
    }
    println!("w1 literal vec1+reshape: {:.3} ms", t0.elapsed().as_secs_f64()*1e3/n as f64);

    let t0 = Instant::now();
    for _ in 0..n {
        let l = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32, &[8192, 64],
            unsafe { std::slice::from_raw_parts(m.w1.as_ptr() as *const u8, m.w1.len()*4) }).unwrap();
        std::hint::black_box(l);
    }
    println!("w1 literal untyped_data:  {:.3} ms", t0.elapsed().as_secs_f64()*1e3/n as f64);

    // Full step timing
    let t0 = Instant::now();
    for _ in 0..n { rt.step(&mut m, &batch, 0.01).unwrap(); }
    let full = t0.elapsed().as_secs_f64()*1e3/n as f64;
    println!("full step:               {:.3} ms (exec {:.3} ms)", full,
        rt.exec_time.borrow().as_secs_f64()*1e3 / *rt.exec_count.borrow() as f64);
}

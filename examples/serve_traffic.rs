//! Serve a trained model under bursty synthetic traffic.
//!
//! Trains briefly with the snapshot-publish hook on, then replays an
//! open-loop bursty trace against the registry: requests micro-batch under
//! the admission deadline, route speed-aware over the heterogeneous
//! device fleet, and the run prints per-window telemetry plus a latency
//! histogram. A checkpoint round-trips through the registry along the way,
//! proving saved artifacts are servable without a training run.
//!
//! ```bash
//! cargo run --release --example serve_traffic
//! ```

use std::sync::Arc;

use heterosparse::config::{Config, ServePattern};
use heterosparse::coordinator::backend::RefBackend;
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::data::pipeline::ShardedDataset;
use heterosparse::data::synthetic::Generator;
use heterosparse::harness::{run_single, Backend};
use heterosparse::serve::{replay, ReplayOptions, SnapshotRegistry};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.model.features = 2048;
    cfg.model.classes = 256;
    cfg.data.train_samples = 8_000;
    cfg.data.test_samples = 1_000;
    cfg.sgd.lr_bmax = 0.3;
    cfg.sgd.num_mega_batches = 6;
    cfg.serve.rate = 6_000.0;
    cfg.serve.duration = 2.0;
    cfg.serve.window = 0.25;
    cfg.validate()?;

    // ---- train briefly, publishing every merged global model ---------------
    let registry = Arc::new(SnapshotRegistry::new());
    let opts = TrainerOptions { publish: Some(registry.clone()), ..Default::default() };
    let train_log = run_single(&cfg, Backend::Auto, opts)?;
    println!(
        "trained {} mega-batches (best P@1 {:.4}); registry holds {} snapshots\n",
        train_log.rows.len(),
        train_log.best_accuracy(),
        registry.history().len()
    );

    // ---- checkpoint → registry round trip ----------------------------------
    let ckpt = std::env::temp_dir().join("hs-serve-traffic.ckpt");
    heterosparse::model::checkpoint::save(&registry.current().unwrap().model, &ckpt)?;
    let from_disk = SnapshotRegistry::new();
    from_disk.load_checkpoint(&ckpt)?;
    println!("checkpoint {} is servable (version {})\n", ckpt.display(), from_disk.latest_version());

    // ---- replay a bursty trace against the final snapshot ------------------
    let (train, _) = {
        let gen = Generator::new(&cfg.model, &cfg.data);
        (gen.generate(cfg.data.train_samples, 1), ())
    };
    let data = Arc::new(ShardedDataset::from_dataset(&train, cfg.data.pipeline.shard_samples));
    let log = replay(
        &cfg,
        data,
        &registry,
        &RefBackend,
        &ReplayOptions {
            pattern: ServePattern::Bursty,
            duration: cfg.serve.duration,
            follow_clock: false,
            train_log: None,
            name: "bursty".to_string(),
            obs: heterosparse::obs::ambient(),
        },
    )?;

    println!("window  t (s)        completed  batches  p50 (ms)  p99 (ms)  peak queue");
    for r in &log.rows {
        println!(
            "{:>6}  {:>4.2}–{:<4.2}  {:>9}  {:>7}  {:>8.3}  {:>8.3}  {:>10}",
            r.window, r.start, r.end, r.completed, r.batches, r.p50_ms, r.p99_ms,
            r.max_queue_depth
        );
    }

    // ---- latency histogram --------------------------------------------------
    // Log-spaced buckets from 0.25ms; stars scale to the largest bucket.
    let latencies: Vec<f64> =
        log.requests.iter().map(|r| (r.completion - r.arrival) * 1e3).collect();
    let edges: Vec<f64> = (0..10).map(|i| 0.25 * 2f64.powi(i)).collect();
    let mut counts = vec![0usize; edges.len() + 1];
    for &l in &latencies {
        counts[edges.partition_point(|&e| e <= l)] += 1;
    }
    let peak = counts.iter().copied().max().unwrap_or(1).max(1);
    println!("\nlatency histogram ({} requests):", latencies.len());
    for (i, &c) in counts.iter().enumerate() {
        let label = match i {
            0 => format!("      < {:>7.2} ms", edges[0]),
            i if i == edges.len() => format!("     >= {:>7.2} ms", edges[i - 1]),
            _ => format!("{:>7.2}–{:<7.2} ms", edges[i - 1], edges[i]),
        };
        println!("{label}  {:<50} {c}", "#".repeat(c * 50 / peak));
    }
    println!(
        "\np50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  throughput {:.0} req/s  served P@1 {:.4}",
        log.latency_percentile_ms(50.0),
        log.latency_percentile_ms(95.0),
        log.latency_percentile_ms(99.0),
        log.throughput(),
        log.served_accuracy()
    );

    anyhow::ensure!(log.total_requests() > 5_000, "trace unexpectedly small");
    anyhow::ensure!(log.latency_percentile_ms(99.0) > 0.0, "latency accounting broke");
    Ok(())
}

//! Distributed-setting extension (paper §3: "the proposed algorithm can be
//! also applied in a distributed environment as long as training batches
//! are dynamically scheduled across computing nodes").
//!
//! We simulate moving the 4-device fleet from one server (NVLink/PCIe-class
//! interconnect) to a cluster (network-class interconnect) by scaling the
//! all-reduce transfer cost, and sweep the mega-batch size. The expectation
//! from the paper's own analysis (§2.3: in a distributed PS the model
//! traffic must be amortized with elastic averaging) is that the optimal
//! merging frequency *drops* as the interconnect slows: on a single server
//! mega=20 is fine, over a network large mega-batches win because every
//! merge costs hundreds of ms.

use heterosparse::config::{Config, DataProfile, Strategy};
use heterosparse::coordinator::backend::RefBackend;
use heterosparse::coordinator::engine_sim::SimEngine;
use heterosparse::coordinator::trainer::{Trainer, TrainerOptions};
use heterosparse::coordinator::DevicePool;
use heterosparse::harness::{bench_config, make_data};
use heterosparse::runtime::CostModel;
use heterosparse::util::bench::Table;

fn run(cfg: &Config, xfer_scale: f64) -> anyhow::Result<(f64, f64, f64)> {
    let (train, test) = make_data(cfg);
    let backend = RefBackend;
    let mut cost = CostModel::default();
    cost.t_per_param_xfer *= xfer_scale;
    cost.t_merge_fixed *= xfer_scale.sqrt(); // latency grows slower than bw shrinks
    let engine = Box::new(SimEngine::new(&backend, DevicePool::roster(cfg), cost));
    let mut trainer = Trainer::new(cfg.clone(), engine, &backend, TrainerOptions::default());
    let log = trainer.run(&train, &test)?;
    let merge_total: f64 = log.rows.iter().map(|r| r.merge_time).sum();
    let clock = log.rows.last().map(|r| r.clock).unwrap_or(0.0);
    Ok((log.best_accuracy(), clock, merge_total / clock.max(1e-9)))
}

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&[
        "interconnect",
        "mega-batch",
        "best P@1",
        "clock (s)",
        "merge share",
    ]);
    for (label, scale) in [("single-server (1x)", 1.0), ("rack network (30x)", 30.0), ("WAN-ish (300x)", 300.0)] {
        for mega in [4usize, 20, 100] {
            let mut cfg = bench_config(DataProfile::Amazon, 4, Strategy::Adaptive);
            cfg.sgd.mega_batches = mega;
            cfg.sgd.num_mega_batches = (240 / mega).max(2);
            let (acc, clock, share) = run(&cfg, scale)?;
            table.row(&[
                label.to_string(),
                format!("{mega}"),
                format!("{acc:.4}"),
                format!("{clock:.2}"),
                format!("{:.1}%", share * 100.0),
            ]);
        }
    }
    table.print("Adaptive SGD beyond one server: merging frequency vs interconnect cost");
    println!(
        "\n(The optimal mega-batch size grows with interconnect cost — the paper's\n\
         premise for why distributed deployments must amortize model traffic.)"
    );
    Ok(())
}

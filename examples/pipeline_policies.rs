//! Data-plane demo: train the same heavy-tailed corpus under each batch
//! composition policy and watch per-batch cost dispersion (nnz CV) change
//! while the elastic scheduler runs on top.
//!
//! ```bash
//! cargo run --release --example pipeline_policies
//! ```

use heterosparse::config::{CompositionPolicy, Config};
use heterosparse::harness::{run_single, Backend};

fn main() -> anyhow::Result<()> {
    let mut base = Config::default();
    base.data.train_samples = 6_000;
    base.data.test_samples = 800;
    base.data.nnz_sigma = 1.2; // heavy-tailed nnz: composition has work to do
    base.sgd.lr_bmax = 0.3;
    base.sgd.num_mega_batches = 6;
    base.validate()?;

    println!(
        "pipeline demo: {} devices, shard_samples={}, queue_depth={}",
        base.devices.count, base.data.pipeline.shard_samples, base.data.pipeline.queue_depth
    );
    println!("\npolicy        nnz CV    best P@1  clock(s)  pool hit%");
    for policy in CompositionPolicy::all() {
        let mut cfg = base.clone();
        cfg.data.pipeline.policy = policy;
        let log = run_single(&cfg, Backend::Auto, Default::default())?;
        let last = log.rows.last().expect("run produced rows");
        let gets = last.pipeline.pool_hits + last.pipeline.pool_misses;
        let hit_pct = if gets == 0 {
            0.0
        } else {
            100.0 * last.pipeline.pool_hits as f64 / gets as f64
        };
        println!(
            "{:<12}  {:<8.4}  {:<8.4}  {:<8.2}  {:.1}",
            policy.name(),
            log.mean_nnz_cv(),
            log.best_accuracy(),
            last.clock,
            hit_pct
        );
    }
    println!(
        "\nnnz_balanced should show the lowest CV (stable batch cost), nnz_sorted the highest \
         (the stress case the paper's Fig. 2 instability stems from)."
    );
    Ok(())
}

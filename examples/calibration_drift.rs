//! Calibration under drift: one device throttles mid-run, the estimator
//! tracks the ramp, and the batch grid re-balances.
//!
//! Four homogeneous simulated devices train adaptive SGD with the
//! calibration plane enabled. A scripted trace throttles device 0 to 2.2×
//! a third of the way in (over a 2-mega-batch ramp) and recovers it at
//! two thirds. The printed trace shows the scripted multiplier, the
//! estimator's view of it (`est d0`), and the batch-size grid chasing the
//! drift — smaller batches on the throttled device, restored after
//! recovery — with per-device update counts staying near-equal
//! throughout.
//!
//! ```bash
//! cargo run --release --example calibration_drift
//! ```

use heterosparse::config::Config;
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::harness::{run_single, Backend};
use heterosparse::tuning::multiplier_at;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.data.train_samples = 8_000;
    cfg.data.test_samples = 1_000;
    cfg.sgd.lr_bmax = 0.3;
    cfg.sgd.num_mega_batches = 12;
    cfg.devices.speed_factors = vec![1.0; 4];
    cfg.devices.jitter = 0.0; // keep the printed trace crisp
    let throttle_at = 4;
    let recover_at = 8;
    cfg.calibration.enabled = true;
    cfg.calibration.step_obs = 1;
    cfg.calibration.events = vec![
        format!("at_mb={throttle_at} device=0 factor=2.2 ramp=2"),
        format!("at_mb={recover_at} device=0 factor=1.0 ramp=2"),
    ];
    cfg.validate()?;
    let trace = cfg.calibration.parsed_events()?;

    println!(
        "calibration drift: 4 homogeneous devices; device 0 ramps to 2.2x its speed \
         factor at mega-batch {throttle_at} and recovers at {recover_at};\n\
         the calibration plane estimates costs online and re-seeds the batch grid.\n"
    );

    let log = run_single(&cfg, Backend::Auto, TrainerOptions::default())?;

    println!("mega-batch  drift d0  est d0  batch grid          updates             P@1");
    for r in &log.rows {
        let est = r.cost_speed.first().copied().unwrap_or(0.0);
        println!(
            "{:>10}  {:>8.2}  {:>6}  {:<18}  {:<18}  {:.4}",
            r.mega_batch,
            multiplier_at(&trace, 0, r.mega_batch),
            if est > 0.0 { format!("{est:.2}") } else { "—".to_string() },
            format!("{:?}", r.batch_sizes),
            format!("{:?}", r.updates),
            r.accuracy,
        );
    }
    println!(
        "\nrun update balance (max/min per-device updates, 1.0 = ideal): {:.2}",
        log.update_balance()
    );
    let clock = log.rows.last().map(|r| r.clock).unwrap_or(0.0);
    println!("final P@1 {:.4} over {clock:.2}s of virtual training", log.final_accuracy());
    Ok(())
}

//! Ablation of Algorithm 2's design choices (DESIGN.md calls these out):
//! perturbation on/off, momentum on/off, batch scaling on/off — all on the
//! same 4-device heterogeneous fleet and sample budget.

use heterosparse::config::Config;
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::harness::{run_single, Backend};
use heterosparse::util::bench::Table;

fn base() -> Config {
    let mut cfg = Config::default();
    cfg.data.train_samples = 10_000;
    cfg.data.test_samples = 1_200;
    cfg.sgd.lr_bmax = 0.3;
    cfg.sgd.num_mega_batches = 12;
    cfg.validate().unwrap();
    cfg
}

fn main() -> anyhow::Result<()> {
    let variants: Vec<(&str, Config)> = vec![
        ("full adaptive", base()),
        ("no perturbation", {
            let mut c = base();
            c.merge.perturbation = false;
            c
        }),
        ("no momentum", {
            let mut c = base();
            c.merge.momentum = 0.0;
            c
        }),
        ("no batch scaling", {
            let mut c = base();
            c.strategy.batch_scaling = false;
            c
        }),
        ("no scaling, no pert", {
            let mut c = base();
            c.strategy.batch_scaling = false;
            c.merge.perturbation = false;
            c
        }),
    ];

    let mut table = Table::new(&["variant", "best P@1", "final P@1", "clock (s)", "pert freq"]);
    for (name, cfg) in variants {
        let log = run_single(&cfg, Backend::Auto, TrainerOptions::default())?;
        table.row(&[
            name.to_string(),
            format!("{:.4}", log.best_accuracy()),
            format!("{:.4}", log.final_accuracy()),
            format!("{:.2}", log.rows.last().unwrap().clock),
            format!("{:.2}", log.perturbation_frequency()),
        ]);
    }
    table.print("Algorithm 1 + 2 ablation (adaptive SGD components)");
    Ok(())
}

//! End-to-end driver (DESIGN.md §6): full-stack training on a real workload.
//!
//! All three layers compose here, with Python nowhere on the path:
//!   L1 Pallas gather-SpMM + online-softmax kernels (inside the HLO),
//!   L2 JAX MLP AOT-lowered per batch-size bucket,
//!   L3 this Rust coordinator: threaded GPU-manager workers, dynamic
//!      scheduling, Algorithm 1 + 2, heterogeneous device simulation.
//!
//! Scale: with `make artifacts-e2e` this trains a ≈10.5M-parameter model
//! (F=65536, H=128, C=16384) for several hundred real PJRT SGD steps on an
//! Amazon-670k-profile synthetic corpus, evaluating P@1 after every
//! mega-batch and logging the loss/accuracy curve to runs/e2e/.
//! Without the e2e artifacts it falls back to the default ("small")
//! artifact set so the driver always exercises the real path.
//!
//! ```bash
//! make artifacts-e2e && cargo run --release --example xml_train
//! ```

use std::path::Path;

use heterosparse::config::{Config, ExecMode};
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::harness::{run_single, Backend};
use heterosparse::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let (cfg, scale) = build_config()?;
    println!(
        "xml_train e2e: {} parameters, {} devices, threaded real engine, profile={} [{scale}]",
        cfg.model.param_count(),
        cfg.devices.count,
        cfg.data.profile.name(),
    );

    let opts = TrainerOptions { verbose: true, ..Default::default() };
    let log = run_single(&cfg, Backend::Pjrt, opts)?;

    let total_steps: u64 =
        log.rows.iter().map(|r| r.updates.iter().sum::<u64>()).sum();
    println!("\n==== e2e summary ====");
    println!("SGD steps executed (real PJRT): {total_steps}");
    println!(
        "loss: {:.4} -> {:.4}",
        log.rows.first().map(|r| r.loss).unwrap_or(0.0),
        log.rows.last().map(|r| r.loss).unwrap_or(0.0)
    );
    println!("best P@1: {:.4}", log.best_accuracy());
    println!(
        "training clock {:.1}s (wall {:.1}s incl. eval/compile)",
        log.rows.last().map(|r| r.clock).unwrap_or(0.0),
        t0.elapsed().as_secs_f64()
    );
    log.write_csv(Path::new("runs/e2e/curve.csv"))?;
    log.write_json(Path::new("runs/e2e/curve.json"))?;
    println!("curve written to runs/e2e/curve.csv");

    anyhow::ensure!(total_steps >= 100, "e2e must run at least a few hundred steps");
    anyhow::ensure!(
        log.rows.last().unwrap().loss < log.rows.first().unwrap().loss,
        "loss must decrease over the run"
    );
    Ok(())
}

/// Prefer the large e2e artifact set; fall back to the default one.
fn build_config() -> anyhow::Result<(Config, &'static str)> {
    let mut cfg = Config::default();
    cfg.runtime.mode = ExecMode::Real;
    cfg.data.train_samples = 30_000;
    cfg.data.test_samples = 2_000;
    cfg.sgd.lr_bmax = 0.3;

    let e2e_dir = Path::new("artifacts/e2e");
    if let Ok(m) = Manifest::load(e2e_dir) {
        cfg.runtime.artifacts_dir = "artifacts/e2e".to_string();
        cfg.model = m.dims.clone();
        cfg.sgd.b_min = m.b_min;
        cfg.sgd.b_max = m.b_max;
        cfg.sgd.beta = m.beta;
        cfg.sgd.initial_batch = m.b_max;
        cfg.sgd.mega_batches = 16; // 16 × 256 = 4096 samples per mega-batch
        cfg.sgd.num_mega_batches = 20;
        cfg.data.avg_nnz = 48.0; // Amazon-670k-like density at K=64
        cfg.validate()?;
        Ok((cfg, "e2e artifacts (≈10.5M params)"))
    } else {
        cfg.sgd.mega_batches = 25;
        cfg.sgd.num_mega_batches = 14;
        cfg.validate()?;
        Ok((cfg, "default artifacts (small profile) — run `make artifacts-e2e` for full scale"))
    }
}

//! Quickstart: train the paper's sparse MLP with Adaptive SGD on four
//! simulated heterogeneous devices and print the accuracy curve.
//!
//! ```bash
//! make artifacts            # once — AOT-compiles the JAX/Pallas model
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the PJRT artifacts when present (the production path) and falls
//! back to the built-in reference numerics otherwise, so it always runs.

use heterosparse::config::Config;
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::harness::{run_single, Backend};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.data.train_samples = 8_000;
    cfg.data.test_samples = 1_000;
    cfg.sgd.lr_bmax = 0.3;
    cfg.sgd.num_mega_batches = 8;
    cfg.validate()?;

    println!(
        "quickstart: adaptive SGD, {} devices (speed factors {:?}), {}-parameter model",
        cfg.devices.count,
        cfg.devices.speed_factors,
        cfg.model.param_count()
    );

    let opts = TrainerOptions { verbose: true, ..Default::default() };
    let log = run_single(&cfg, Backend::Auto, opts)?;

    println!("\nmega-batch  clock(s)  loss     P@1     batch sizes");
    for r in &log.rows {
        println!(
            "{:>10}  {:>8.3}  {:<7.4}  {:<6.4}  {:?}",
            r.mega_batch, r.clock, r.loss, r.accuracy, r.batch_sizes
        );
    }
    println!("\nbest P@1: {:.4}", log.best_accuracy());
    log.write_csv(std::path::Path::new("runs/quickstart.csv"))?;
    println!("curve written to runs/quickstart.csv");
    Ok(())
}

//! Elastic failover: lose a fast device mid-training, recover later.
//!
//! The run starts on four simulated heterogeneous devices, loses device 0 —
//! the *fastest* one, the worst case for throughput — a third of the way
//! in, and gets it back at two thirds. The pool renormalizes Algorithm 2's
//! merge weights over whatever subset is active, so training rides through
//! both transitions; the printed P@1 trajectory shows the dip-free recovery.
//!
//! ```bash
//! cargo run --release --example elastic_failover
//! ```

use heterosparse::config::Config;
use heterosparse::coordinator::trainer::TrainerOptions;
use heterosparse::harness::{run_single, Backend};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.data.train_samples = 8_000;
    cfg.data.test_samples = 1_000;
    cfg.sgd.lr_bmax = 0.3;
    cfg.sgd.num_mega_batches = 9;
    let lose_at = 3;
    let recover_at = 6;
    cfg.elastic.events = vec![
        format!("at_mb={lose_at} remove_id=0"),
        format!("at_mb={recover_at} add_id=0"),
    ];
    cfg.validate()?;

    println!(
        "elastic failover: adaptive SGD on {} devices (speed factors {:?});\n\
         device 0 (the fastest) drops out at mega-batch {lose_at} and returns at {recover_at}\n",
        cfg.devices.count, cfg.devices.speed_factors,
    );

    let log = run_single(&cfg, Backend::Auto, TrainerOptions::default())?;

    println!("mega-batch  devices  clock(s)  loss     P@1     events");
    for r in &log.rows {
        let events: Vec<String> = r
            .pool_events
            .iter()
            .map(|e| format!("{} device {}", e.action, e.device))
            .collect();
        println!(
            "{:>10}  {:>7}  {:>8.3}  {:<7.4}  {:<6.4}  {}",
            r.mega_batch,
            r.active_devices.len(),
            r.clock,
            r.loss,
            r.accuracy,
            events.join(", ")
        );
    }

    let before = log.rows[..lose_at].iter().map(|r| r.accuracy).fold(0.0, f64::max);
    let after = log.rows[recover_at..].iter().map(|r| r.accuracy).fold(0.0, f64::max);
    println!(
        "\nbest P@1 before the failure: {before:.4}; after recovery: {after:.4}\n\
         ({} pool events recorded in the run log)",
        log.pool_events.len()
    );
    anyhow::ensure!(
        log.device_counts() == vec![4, 4, 4, 3, 3, 3, 4, 4, 4],
        "unexpected pool trajectory: {:?}",
        log.device_counts()
    );
    anyhow::ensure!(after >= before * 0.8, "training failed to recover after the pool event");
    Ok(())
}
